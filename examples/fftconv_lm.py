"""The paper's technique inside the LM stack: train a small Hyena-style LM
whose sequence mixer is the repro.core FFT convolution, and verify its decode
path (history-cache direct convolution) matches training-mode outputs.

    PYTHONPATH=src python examples/fftconv_lm.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ArchConfig, ShapeConfig
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    arch = ArchConfig(
        name="fftconv-lm", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=4096,
        segments=(("fftconv_mlp", 4),), fftconv_rank=16,
        compute_dtype="float32")
    shape = ShapeConfig("train", 128, 8, "train")
    trainer = Trainer(arch, shape, None,
                      TrainerConfig(ckpt_dir="/tmp/repro_fftconv",
                                    ckpt_every=50),
                      AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60))
    params, _, hist = trainer.run(30)
    print(f"fftconv-LM: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")

    # decode == forward consistency (FFT conv train path vs history-cache
    # direct conv decode path)
    toks = jax.random.randint(jax.random.key(0), (2, 16), 0, arch.vocab_size)
    logits_full, _ = lm.forward(params, arch, {"tokens": toks})
    cache = lm.init_cache(arch, 2, 16)
    outs = []
    for t in range(16):
        lg, cache = lm.decode_step(params, arch, cache,
                                   {"tokens": toks[:, t:t + 1]})
        outs.append(lg)
    err = float(jnp.max(jnp.abs(logits_full.astype(jnp.float32)
                                - jnp.concatenate(outs, 1))))
    print(f"decode-vs-forward max |delta logits| = {err:.2e}")
    assert err < 2e-2


if __name__ == "__main__":
    main()
