"""Distributed 2D FFT (the paper's §5.3 experiment) on 8 emulated devices:
slab decomposition, explicit collectives, three communication backends.

    PYTHONPATH=src python examples/fft2d_distributed.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time                                   # noqa: E402

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import Planner, fft2_slab, fft3_pencil, ifft2_slab  # noqa: E402
from repro.core.algo import to_pair           # noqa: E402


def main() -> None:
    mesh = jax.make_mesh((8,), ("fft",))
    planner = Planner(mode="estimate", backends=("jnp",))
    rng = np.random.default_rng(0)

    n, m = 512, 512
    x = rng.standard_normal((n, m)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("fft", None)))
    ref = np.fft.rfft2(x)

    for comm in ("collective", "pipelined", "agas"):
        fn = jax.jit(lambda a, _c=comm: fft2_slab(a, mesh, "fft", planner,
                                                  comm=_c))
        out = jax.block_until_ready(fn(xs))
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(xs))
        dt = time.perf_counter() - t0
        z = np.asarray(out[0])[:, :m // 2 + 1] + 1j * np.asarray(out[1])[:, :m // 2 + 1]
        err = np.max(np.abs(z - ref)) / np.max(np.abs(ref))
        print(f"fft2_slab comm={comm:10s} t={dt * 1e3:7.1f}ms rel_err={err:.2e}")

    # roundtrip through the inverse
    c = fft2_slab(xs, mesh, "fft", planner)
    back = ifft2_slab(c, mesh, "fft", m, planner)
    print("ifft2 roundtrip err:", float(np.max(np.abs(np.asarray(back) - x))))

    # 3D pencil decomposition (P3DFFT-style) on a 4x2 mesh
    mesh2 = jax.make_mesh((4, 2), ("mx", "my"))
    xc = (rng.standard_normal((32, 64, 128)).astype(np.float32)
          + 1j * rng.standard_normal((32, 64, 128)).astype(np.float32))
    pair = (jax.device_put(np.real(xc).astype(np.float32),
                           NamedSharding(mesh2, P("mx", "my", None))),
            jax.device_put(np.imag(xc).astype(np.float32),
                           NamedSharding(mesh2, P("mx", "my", None))))
    rr, ri = fft3_pencil(pair, mesh2, ("mx", "my"), planner)
    ref3 = np.fft.fftn(xc)
    err3 = np.max(np.abs((np.asarray(rr) + 1j * np.asarray(ri)) - ref3)) \
        / np.max(np.abs(ref3))
    print(f"fft3_pencil (4x2 mesh) rel_err={err3:.2e}")


if __name__ == "__main__":
    main()
