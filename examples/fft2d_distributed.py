"""Distributed 2D FFT (the paper's §5.3 experiment) on 8 emulated devices:
slab decomposition, explicit collectives, the comm backends, and the two
backend-selection modes (roofline "auto" vs on-mesh-timed "measure").

    PYTHONPATH=src python examples/fft2d_distributed.py
    PYTHONPATH=src python examples/fft2d_distributed.py --comm measure \
        --wisdom /tmp/fft_wisdom.json   # rerun: zero re-measurement
"""

import argparse
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time                                   # noqa: E402

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import (Planner, fft2_slab, fft3_pencil, ifft2_slab,  # noqa: E402
                        ifft3_pencil, irfft3_pencil, rfft3_pencil)

COMM_CHOICES = ("collective", "pipelined", "agas", "auto", "measure")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--comm", choices=COMM_CHOICES, default=None,
                    help="run a single exchange backend / selection mode "
                         "(default: sweep them all)")
    ap.add_argument("--wisdom", default=None,
                    help="wisdom JSON path shared by plan + comm autotuners "
                         "(comm=measure verdicts persist across runs)")
    args = ap.parse_args()
    sweep = COMM_CHOICES if args.comm is None else (args.comm,)

    mesh = jax.make_mesh((8,), ("fft",))
    planner = Planner(mode="estimate", backends=("jnp",),
                      wisdom_path=args.wisdom)
    rng = np.random.default_rng(0)

    n, m = 512, 512
    x = rng.standard_normal((n, m)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("fft", None)))
    ref = np.fft.rfft2(x)

    for comm in sweep:
        fn = jax.jit(lambda a, _c=comm: fft2_slab(a, mesh, "fft", planner,
                                                  comm=_c))
        out = jax.block_until_ready(fn(xs))
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(xs))
        dt = time.perf_counter() - t0
        z = np.asarray(out[0])[:, :m // 2 + 1] + 1j * np.asarray(out[1])[:, :m // 2 + 1]
        err = np.max(np.abs(z - ref)) / np.max(np.abs(ref))
        print(f"fft2_slab comm={comm:10s} t={dt * 1e3:7.1f}ms rel_err={err:.2e}")

    # roundtrip through the inverse
    c = fft2_slab(xs, mesh, "fft", planner)
    back = ifft2_slab(c, mesh, "fft", m, planner)
    print("ifft2 roundtrip err:", float(np.max(np.abs(np.asarray(back) - x))))

    # 3D pencil decomposition (P3DFFT-style) on a 4x2 mesh, per comm backend
    mesh2 = jax.make_mesh((4, 2), ("mx", "my"))
    xc = (rng.standard_normal((32, 64, 128)).astype(np.float32)
          + 1j * rng.standard_normal((32, 64, 128)).astype(np.float32))
    pair = (jax.device_put(np.real(xc).astype(np.float32),
                           NamedSharding(mesh2, P("mx", "my", None))),
            jax.device_put(np.imag(xc).astype(np.float32),
                           NamedSharding(mesh2, P("mx", "my", None))))
    ref3 = np.fft.fftn(xc)
    for comm in sweep:
        rr, ri = fft3_pencil(pair, mesh2, ("mx", "my"), planner, comm=comm)
        err3 = np.max(np.abs((np.asarray(rr) + 1j * np.asarray(ri)) - ref3)) \
            / np.max(np.abs(ref3))
        print(f"fft3_pencil comm={comm:10s} (4x2 mesh) rel_err={err3:.2e}")
    if args.wisdom:
        from repro.core import comm as comm_mod
        verdicts = {k: planner.wisdom.get(k)["backend"]
                    for k in planner.wisdom.keys("comm/")}
        print(f"comm wisdom at {args.wisdom}: {verdicts} "
              f"(timing probes this run: {comm_mod.MEASURE_STATS['timed']})")

    # mixed per-axis selection: pipeline the row-communicator exchange only
    rr, ri = fft3_pencil(pair, mesh2, ("mx", "my"), planner,
                         comm=("collective", "pipelined"))
    br, bi = ifft3_pencil((rr, ri), mesh2, ("mx", "my"), planner,
                          comm=("collective", "pipelined"))
    back3 = np.asarray(br) + 1j * np.asarray(bi)
    print("ifft3 roundtrip err:", float(np.max(np.abs(back3 - xc))))

    # 3D r2c/c2r pencil roundtrip (padded half spectrum, as the 2D path)
    xr3 = rng.standard_normal((32, 64, 128)).astype(np.float32)
    xr3s = jax.device_put(xr3, NamedSharding(mesh2, P("mx", "my", None)))
    re3, im3 = rfft3_pencil(xr3s, mesh2, ("mx", "my"), planner, comm="auto")
    z3 = (np.asarray(re3)[..., :128 // 2 + 1]
          + 1j * np.asarray(im3)[..., :128 // 2 + 1])
    err_r = np.max(np.abs(z3 - np.fft.rfftn(xr3))) \
        / np.max(np.abs(np.fft.rfftn(xr3)))
    back_r = irfft3_pencil((re3, im3), mesh2, ("mx", "my"), 128, planner,
                           comm="auto")
    print(f"rfft3_pencil rel_err={err_r:.2e}  irfft3 roundtrip err:",
          float(np.max(np.abs(np.asarray(back_r) - xr3))))


if __name__ == "__main__":
    main()
