"""Distributed N-D FFT through the planned front-end (the paper's §5.3
experiment) on 8 emulated devices: `plan_nd` scores local vs slab vs pencil
decompositions (with mesh-axis assignment), resolves the exchange backends
(roofline "auto" or on-mesh-timed "measure"), and the `fftn` family executes
the plan — numpy-exact shapes, mixed-radix meshes and batch dims included.

    PYTHONPATH=src python examples/fft2d_distributed.py
    PYTHONPATH=src python examples/fft2d_distributed.py --comm measure \
        --wisdom /tmp/fft_wisdom.json   # rerun: zero re-measurement
"""

import argparse
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time                                   # noqa: E402

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402

from repro.core import (Planner, fftn, ifftn, irfftn, plan_nd,  # noqa: E402
                        rfftn)

COMM_CHOICES = ("collective", "pipelined", "agas", "auto", "measure")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--comm", choices=COMM_CHOICES, default=None,
                    help="run a single exchange backend / selection mode "
                         "(default: sweep them all)")
    ap.add_argument("--wisdom", default=None,
                    help="wisdom JSON path shared by plan + comm + dfft "
                         "autotuners (measure verdicts persist across runs)")
    args = ap.parse_args()
    sweep = COMM_CHOICES if args.comm is None else (args.comm,)

    mesh = jax.make_mesh((8,), ("fft",))
    mesh2 = jax.make_mesh((4, 2), ("mx", "my"))
    planner = Planner(mode="estimate", backends=("jnp",),
                      wisdom_path=args.wisdom)
    rng = np.random.default_rng(0)

    # ------------------------------------------------------------------
    # the decomposition planner at work: one front-end, every layout
    # ------------------------------------------------------------------
    for shape, kind, m in (((64, 64), "r2c", mesh),
                           ((512, 512), "r2c", mesh),
                           ((32, 64, 128), "c2c", mesh2),
                           ((10, 36), "r2c", mesh)):     # mixed radix
        nd = plan_nd(shape, kind, mesh=m, planner=planner)
        print(f"plan_nd{shape} {kind}: decomp={nd.decomp:7s} "
              f"axes={nd.mesh_axes} comm={nd.comm} "
              f"est={nd.est_cost * 1e6:8.1f}us")

    # 2D r2c through the front-end, per comm spec
    n, m = 512, 512
    x = rng.standard_normal((n, m)).astype(np.float32)
    ref = np.fft.rfft2(x)
    for comm in sweep:
        nd = plan_nd((n, m), "r2c", mesh=mesh, comm=comm, planner=planner,
                     decomp="slab", axes=("fft",))
        fn = jax.jit(lambda a, _p=nd: rfftn(a, mesh=mesh, plan=_p,
                                            planner=planner))
        out = jax.block_until_ready(fn(x))
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(x))
        dt = time.perf_counter() - t0
        z = np.asarray(out[0]) + 1j * np.asarray(out[1])
        err = np.max(np.abs(z - ref)) / np.max(np.abs(ref))
        print(f"rfftn slab comm={comm:10s} t={dt * 1e3:7.1f}ms "
              f"rel_err={err:.2e}")

    # roundtrip through the inverse (the same plan serves both directions)
    nd = plan_nd((n, m), "r2c", mesh=mesh, planner=planner)
    back = irfftn(rfftn(x, mesh=mesh, plan=nd, planner=planner),
                  shape=(n, m), mesh=mesh, plan=nd, planner=planner)
    print("irfftn roundtrip err:", float(np.max(np.abs(np.asarray(back) - x))))

    # 3D pencil decomposition (P3DFFT-style) on the 4x2 mesh, per comm spec
    xc = (rng.standard_normal((32, 64, 128)).astype(np.float32)
          + 1j * rng.standard_normal((32, 64, 128)).astype(np.float32))
    ref3 = np.fft.fftn(xc)
    for comm in sweep:
        nd3 = plan_nd((32, 64, 128), "c2c", mesh=mesh2, comm=comm,
                      planner=planner, decomp="pencil", axes=("mx", "my"))
        rr, ri = fftn(xc, mesh=mesh2, plan=nd3, planner=planner)
        err3 = np.max(np.abs((np.asarray(rr) + 1j * np.asarray(ri)) - ref3)) \
            / np.max(np.abs(ref3))
        print(f"fftn pencil comm={comm:10s} (4x2 mesh) rel_err={err3:.2e}")
    if args.wisdom:
        from repro.core import comm as comm_mod
        verdicts = {k: planner.wisdom.get(k).get("backend",
                                                 planner.wisdom.get(k))
                    for k in planner.wisdom.keys("comm/")}
        print(f"comm wisdom at {args.wisdom}: {verdicts} "
              f"(timing probes this run: {comm_mod.MEASURE_STATS['timed']})")
        print("dfft wisdom:", list(planner.wisdom.keys("dfft/")))

    # mixed per-axis selection + full c2c roundtrip
    ndp = plan_nd((32, 64, 128), "c2c", mesh=mesh2,
                  comm=("collective", "pipelined"), planner=planner,
                  decomp="pencil", axes=("mx", "my"))
    br, bi = ifftn(fftn(xc, mesh=mesh2, plan=ndp, planner=planner),
                   mesh=mesh2, plan=ndp, planner=planner)
    back3 = np.asarray(br) + 1j * np.asarray(bi)
    print("ifftn roundtrip err:", float(np.max(np.abs(back3 - xc))))

    # 3D r2c/c2r roundtrip with a leading batch dim and a mixed-radix mesh
    # (neither X=6 nor Y=10 divides the 4x2 communicators; the padded bands
    # are planned, carried, and cropped by the NdPlan recipe)
    xr3 = rng.standard_normal((2, 6, 10, 128)).astype(np.float32)
    ndr = plan_nd((6, 10, 128), "r2c", mesh=mesh2, planner=planner,
                  decomp="pencil", axes=("mx", "my"))
    re3, im3 = rfftn(xr3, mesh=mesh2, plan=ndr, planner=planner, ndim=3)
    z3 = np.asarray(re3) + 1j * np.asarray(im3)
    ref_r = np.fft.rfftn(xr3, axes=(-3, -2, -1))
    err_r = np.max(np.abs(z3 - ref_r)) / np.max(np.abs(ref_r))
    back_r = irfftn((re3, im3), shape=(6, 10, 128), mesh=mesh2, plan=ndr,
                    planner=planner)
    print(f"rfftn pencil(batch,mixed-radix) rel_err={err_r:.2e}  "
          "irfftn roundtrip err:",
          float(np.max(np.abs(np.asarray(back_r) - xr3))))


if __name__ == "__main__":
    main()
