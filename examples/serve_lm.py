"""End-to-end serving driver: batched requests through the continuous-
batching decode loop (prefill + decode with per-architecture state caches).

    PYTHONPATH=src python examples/serve_lm.py --arch olmo-1b --requests 12
"""

import argparse
import time

import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import Request, ServeLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    loop = ServeLoop(cfg, batch=args.batch, max_len=256)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for r in range(args.requests):
        loop.submit(Request(r, rng.integers(0, cfg.vocab_size,
                                            args.prompt_len).astype(np.int32),
                            max_new=args.max_new))
    loop.drain()
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in loop.done)
    print(f"served {len(loop.done)} requests, {total} tokens "
          f"in {dt:.1f}s ({total / dt:.1f} tok/s, batch={args.batch}, "
          f"arch={cfg.name} [reduced])")
    for r in loop.done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
