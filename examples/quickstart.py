"""Quickstart: the paper's 2D FFT through the public API.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import Planner, fft_conv, run_variant, VARIANTS


def main() -> None:
    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, 512)).astype(np.float32)
    ref = np.fft.rfft2(x)

    # 1) FFTW-style planning: estimate (cost model) picks the factorization
    planner = Planner(mode="estimate", backends=("jnp",))
    plan = planner.plan(512, kind="r2c")
    print(f"plan for n=512 r2c: factors={plan.factors} backend={plan.backend}")

    # 2) the paper's implementation variants all agree with numpy
    for name in VARIANTS:
        out = run_variant(name, x, planner)
        z = np.asarray(out[0]) + 1j * np.asarray(out[1])
        err = np.max(np.abs(z - ref)) / np.max(np.abs(ref))
        print(f"variant {name:13s} rel_err={err:.2e}")

    # 3) FFT convolution (the technique as an LM sequence mixer)
    u = rng.standard_normal((2, 256, 8)).astype(np.float32)
    k = (rng.standard_normal((8, 256))
         * np.exp(-np.arange(256) / 16.0)).astype(np.float32)
    y = fft_conv(u, k, planner)
    print(f"fft_conv output {y.shape}, finite={bool(np.isfinite(np.asarray(y)).all())}")


if __name__ == "__main__":
    main()
