"""End-to-end training driver: a ~100M-parameter granite-family model,
synthetic data, full fault-tolerant runtime (async checkpoints, restart).

The default (--scale small, ~20M params, 100 steps) finishes on this CPU
container in a few minutes; --scale 100m is the full-size run for real
hardware (same code path).

    PYTHONPATH=src python examples/train_lm.py --steps 100
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.models.config import ShapeConfig
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def build_arch(scale: str):
    base = get_config("granite_8b")
    if scale == "100m":
        return dataclasses.replace(
            base, name="granite-100m", num_layers=8, d_model=768,
            num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32768)
    return dataclasses.replace(
        base, name="granite-20m", num_layers=4, d_model=384, num_heads=6,
        num_kv_heads=2, d_ff=1024, vocab_size=8192)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "100m"], default="small")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    arch = build_arch(args.scale)
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    trainer = Trainer(
        arch, shape, mesh=None,
        tcfg=TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=25),
        ocfg=AdamWConfig(lr=6e-4, warmup_steps=max(args.steps // 20, 1),
                         total_steps=args.steps))
    from repro.models.params import param_count
    from repro.models import lm
    print(f"arch={arch.name} params={param_count(lm.model_meta(arch)) / 1e6:.1f}M")
    _, _, hist = trainer.run(args.steps)
    print(f"step 0 loss={hist[0]['loss']:.4f} -> "
          f"step {len(hist) - 1} loss={hist[-1]['loss']:.4f}")
    print(f"checkpoints: {trainer.ckpt.all_steps()} (async, atomic, keep-3)")
    print(f"straggler events: {len(trainer.straggler_events)}")


if __name__ == "__main__":
    main()
