#!/usr/bin/env bash
# Tier-1 minus the slow multi-device subprocess suites — seconds instead of
# minutes, for quick local iteration.  Full tier-1 remains:
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q -m "not slow" "$@"
