#!/usr/bin/env bash
# Headless CI entry point: the quick suite first for fast signal, then the
# full tier-1 command (which adds the slow 8-fake-device subprocess suites —
# the distributed comm/measure matrix in tests/_dist_worker.py).
#
#   scripts/ci.sh            # everything (what CI runs)
#   scripts/ci.sh --fast     # only the quick suite (local pre-push check)
set -euo pipefail
cd "$(dirname "$0")/.."

bash scripts/test_fast.sh

if [[ "${1:-}" == "--fast" ]]; then
    exit 0
fi

# full tier-1: the fast tests rerun from cache-warm bytecode in seconds;
# the real added cost is the multi-device distributed matrix.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q

# end-to-end smoke of the planned N-D front-end on an 8-way CPU mesh:
# plan_nd decomposition choice, auto comm resolution, slab + pencil
# execution, mixed-radix + batched paths — the example exercises the whole
# stack, not just units.
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python examples/fft2d_distributed.py --comm auto
