#!/usr/bin/env bash
# CI benchmark subset + regression gate (see benchmarks/README.md).
#
#   scripts/bench_ci.sh                    # run, emit BENCH_ci.json, gate
#                                          # against benchmarks/baseline_ci.json
#   scripts/bench_ci.sh --write-baseline   # refresh the committed baseline
#   BENCH_SKIP_GATE=1 scripts/bench_ci.sh  # run + artifact, gate reports
#                                          # but never fails (override label)
#
# Extra flags pass through to benchmarks/bench_ci.py (--tolerance,
# --inject-slowdown CASE:FACTOR for the gate-trip demonstration, ...).
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.bench_ci \
    --out BENCH_ci.json --baseline benchmarks/baseline_ci.json "$@"
