import os
import sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d" % (8 if "train8" in sys.argv else 512)
"""Pipeline-parallel evidence artifacts (the full-dims 512-chip pipelined
TRAIN step trips an XLA-CPU backend CHECK failure — 'Invalid binary
instruction opcode copy' while cloning an all-reduce; valid HLO, compiler
bug.  Evidence that the feature works: (a) full-dims pipelined FORWARD at
512 chips, (b) half-dims pipelined TRAIN at 512 chips, (c) bit-correct
loss + grads vs the non-pipelined model at 8 devices in tests)."""
import dataclasses, json, time
import jax, jax.numpy as jnp
from repro.models import lm
from repro.parallel import make_rules
from repro.parallel.pipelined_lm import pipelined_loss_fn, pipeline_param_shardings
from repro.models.params import abstract_tree
from repro.configs import get_config
from repro.optim import AdamWConfig, adamw_update, opt_meta
from repro.launch.dryrun import parse_collectives, PEAK_FLOPS_BF16, HBM_BW, LINK_BW

out = []
mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
rules = make_rules(mesh, pipeline_pods=True)

def record(name, cfg, train):
    meta = lm.model_meta(cfg)
    pspecs = pipeline_param_shardings(mesh, meta, rules)
    params_abs = abstract_tree(meta)
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
             "labels": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    if train:
        om = opt_meta(meta)
        ospecs = {"mu": pipeline_param_shardings(mesh, om["mu"], rules),
                  "nu": pipeline_param_shardings(mesh, om["nu"], rules),
                  "step": None}
        ocfg = AdamWConfig()
        def step(p, o, b):
            (l, m), g = jax.value_and_grad(
                lambda pp, bb: pipelined_loss_fn(pp, cfg, bb, mesh, rules),
                has_aux=True)(p, b)
            p, o, mm = adamw_update(ocfg, g, p, o)
            return p, o, l
        args = (params_abs, abstract_tree(om), batch)
        shardings = (pspecs, ospecs, None)
    else:
        step = lambda p, b: pipelined_loss_fn(p, cfg, b, mesh, rules)[0]
        args = (params_abs, batch)
        shardings = (pspecs, None)
    t0 = time.perf_counter()
    with mesh:
        comp = jax.jit(step, in_shardings=shardings).lower(*args).compile()
    cost = comp.cost_analysis() or {}
    coll, counts, wire = parse_collectives(comp.as_text(), with_wire=True)
    rec = {"name": name, "compile_seconds": round(time.perf_counter() - t0, 2),
           "hlo_flops_per_device": float(cost.get("flops", 0)),
           "collective_bytes_per_device": coll,
           "collective_wire_bytes_per_device": wire,
           "collective_counts": counts}
    out.append(rec)
    print(name, "OK", rec["compile_seconds"], "s", flush=True)

import sys
case = sys.argv[1] if len(sys.argv) > 1 else "fwd"
if case == "fwd":
    record("pipeline_fwd_full_granite8b_512", get_config("granite_8b"), train=False)
elif case == "train8":
    # train step at 8-dev multi-pod mesh (the scale the XLA CPU backend
    # compiles without tripping its all-reduce-clone CHECK bug)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = make_rules(mesh, pipeline_pods=True)
    record("pipeline_train_full_granite8b_2x2x2", get_config("granite_8b"), train=True)
with open(f"experiments/dryrun/pipeline_evidence_{case}.json", "w") as f:
    json.dump(out, f, indent=1)
print("saved", case)
