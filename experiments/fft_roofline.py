import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=256")

"""Roofline + hillclimb for the paper's own application: the distributed 2D
r2c FFT (2^14 x 2^14, the paper's production problem size) slab-decomposed
over one 256-chip pod.

Each named configuration is one §Perf iteration; this script lowers,
compiles, and prints/records the three roofline terms per step so the
hypothesis -> change -> measure log in EXPERIMENTS.md is reproducible.

  PYTHONPATH=src python experiments/fft_roofline.py --out experiments/fft
"""

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402

import jax            # noqa: E402
import numpy as np    # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import dfft, plan                     # noqa: E402
from repro.launch.dryrun import (HBM_BW, LINK_BW, PEAK_FLOPS_BF16,  # noqa: E402
                                 parse_collectives)

N = 1 << 14           # paper problem: 2^14 x 2^14


def lower_case(name, planner, comm, keep_transposed, chunks=4,
               permuted_cols=False):
    mesh = jax.make_mesh((256,), ("fft",))
    x_abs = jax.ShapeDtypeStruct((N, N), jnp_f32())
    in_sh = NamedSharding(mesh, P("fft", None))

    def fn(x):
        return dfft.fft2_slab(x, mesh, "fft", planner, comm=comm,
                              chunks=chunks, keep_transposed=keep_transposed,
                              permuted_cols=permuted_cols)

    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(fn, in_shardings=(in_sh,)).lower(x_abs)
        compiled = lowered.compile()
    dt = time.perf_counter() - t0

    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    coll, counts, wire = parse_collectives(compiled.as_text(), with_wire=True)
    wire_b = sum(wire.values())

    # exposed-communication model: the pipelined schedule overlaps each
    # chunk's exchange with the next chunk's row FFTs; with c chunks,
    # exposed time ~ max(per-chunk comm, per-chunk compute) summed, lower-
    # bounded by 1/c of the monolithic exchange staying exposed.
    t_coll = wire_b / LINK_BW
    exposed = t_coll / chunks + (chunks - 1) / chunks * max(
        0.0, t_coll / chunks - flops / PEAK_FLOPS_BF16 / chunks) \
        if comm == "pipelined" else t_coll

    rec = {
        "name": name, "compile_seconds": round(dt, 2),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_,
        "collective_operand_bytes": sum(coll.values()),
        "collective_wire_bytes": wire_b,
        "collective_counts": counts,
        "t_compute": flops / PEAK_FLOPS_BF16,
        "t_memory": bytes_ / HBM_BW,
        "t_collective": t_coll,
        "t_collective_exposed": exposed,
    }
    terms = {k: rec[k] for k in ("t_compute", "t_memory")}
    terms["t_collective"] = exposed
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["t_total_max"] = max(terms.values())
    return rec


def jnp_f32():
    import jax.numpy as jnp
    return jnp.float32


def lower_pencil(n3: int = 1024):
    """3D c2c FFT (n3^3) pencil-decomposed over the full 16x16 pod — the
    P3DFFT-style decomposition the paper cites: exchanges stay within
    row/column communicators (16 ranks) instead of the global 256."""
    import jax.numpy as jnp
    from repro.core import fft3_pencil
    from repro.core.plan import Planner
    mesh = jax.make_mesh((16, 16), ("mx", "my"))
    planner = Planner(backends=("jnp",))
    pair = (jax.ShapeDtypeStruct((n3, n3, n3), jnp.float32),) * 2
    sh = NamedSharding(mesh, P("mx", "my", None))

    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(
            lambda r, i: fft3_pencil((r, i), mesh, ("mx", "my"), planner),
            in_shardings=(sh, sh)).lower(*pair)
        compiled = lowered.compile()
    dt = time.perf_counter() - t0
    cost = compiled.cost_analysis() or {}
    coll, counts, wire = parse_collectives(compiled.as_text(), with_wire=True)
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    return {"name": f"pencil3d_{n3}", "compile_seconds": round(dt, 2),
            "hlo_flops_per_device": flops,
            "hlo_bytes_per_device": bytes_,
            "collective_wire_bytes": sum(wire.values()),
            "collective_counts": counts,
            "t_compute": flops / PEAK_FLOPS_BF16,
            "t_memory": bytes_ / HBM_BW,
            "t_collective": sum(wire.values()) / LINK_BW}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--only", default=None)
    ap.add_argument("--pencil", action="store_true")
    args = ap.parse_args()

    if args.pencil:
        rec = lower_pencil()
        print(f"{rec['name']:18s} compile={rec['compile_seconds']:6.1f}s "
              f"t_comp={rec['t_compute'] * 1e3:7.3f}ms "
              f"t_mem={rec['t_memory'] * 1e3:7.3f}ms "
              f"t_coll={rec['t_collective'] * 1e3:7.3f}ms "
              f"colls={rec['collective_counts']}")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            with open(os.path.join(args.out, "fft_pencil3d.json"), "w") as f:
                json.dump(rec, f, indent=1)
        return

    est = plan.Planner(mode="estimate", backends=("jnp",))
    kar = plan.Planner(mode="estimate", backends=("jnp_karatsuba",))

    cases = [
        # paper-faithful baseline: monolithic all_to_all, ordered
        # transforms, 4-matmul complex products, full layout restore
        ("baseline_paper", dict(planner=est, comm="collective",
                                keep_transposed=False)),
        # the paper's own AGAS overhead measurement
        ("agas", dict(planner=est, comm="agas", keep_transposed=False)),
        # beyond-paper #1: skip the second exchange (consumer accepts the
        # transposed spectrum — valid for conv/filter pipelines)
        ("keep_transposed", dict(planner=est, comm="collective",
                                 keep_transposed=True)),
        # beyond-paper #2: Karatsuba 3-matmul complex products
        ("karatsuba", dict(planner=kar, comm="collective",
                           keep_transposed=True)),
        # beyond-paper #3: chunked pipelined exchange (LCI analogue)
        ("pipelined_c4", dict(planner=kar, comm="pipelined",
                              keep_transposed=True, chunks=4)),
        ("pipelined_c8", dict(planner=kar, comm="pipelined",
                              keep_transposed=True, chunks=8)),
        # beyond-paper #4: permuted-order column FFTs (skip digit transpose
        # — one fewer memory pass per column transform)
        ("permuted_cols", dict(planner=est, comm="collective",
                               keep_transposed=True, permuted_cols=True)),
    ]
    results = []
    for name, kw in cases:
        if args.only and args.only != name:
            continue
        rec = lower_case(name, **kw)
        results.append(rec)
        print(f"{name:18s} compile={rec['compile_seconds']:6.1f}s "
              f"t_comp={rec['t_compute'] * 1e3:7.3f}ms "
              f"t_mem={rec['t_memory'] * 1e3:7.3f}ms "
              f"t_coll={rec['t_collective'] * 1e3:7.3f}ms "
              f"exposed={rec['t_collective_exposed'] * 1e3:7.3f}ms "
              f"bneck={rec['bottleneck']} "
              f"max={rec['t_total_max'] * 1e3:7.3f}ms", flush=True)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        with open(os.path.join(args.out, "fft_roofline.json"), "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
