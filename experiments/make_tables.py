"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the per-cell
JSON records emitted by repro.launch.dryrun.

    python experiments/make_tables.py experiments/dryrun > experiments/tables.md
"""

from __future__ import annotations

import glob
import json
import os
import sys

ARCH_ORDER = ["granite_8b", "olmo_1b", "command_r_plus_104b", "granite_3_2b",
              "phi35_moe_42b", "dbrx_132b", "xlstm_1_3b", "zamba2_7b",
              "qwen2_vl_7b", "musicgen_large"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def fmt_b(x):
    if x is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}B"


def improvement_hint(r):
    b = r["bottleneck"]
    kind = r["kind"]
    ar = (r.get("collective_bytes_per_device") or {}).get("all-reduce", 0)
    if kind == "train" and ar > 1e10:
        return ("f32 TP activation all-reduces dominate the wire (2/layer "
                "x fwd+remat+bwd); bf16 reductions + sequence-parallel "
                "reduce-scatter halve it; remat policy trims HBM bytes")
    if b == "memory" and kind == "decode":
        return ("weight+cache streaming bound (classic decode); bf16/int8 "
                "weights, bf16-kept attention (no f32 cache copies), more "
                "batch per chip raise arithmetic intensity")
    if b == "memory":
        return ("activation streaming bound (XLA operand-bytes upper "
                "bound); fusion-friendly bulk stages, bf16 reductions")
    if b == "collective":
        return ("collective-dominated; bf16 partial-sum reductions, "
                "replicated MoE combine buffer, chunked overlap "
                "(LCI-analogue) cut exposed time")
    return "compute-bound; near roofline if MXU utilization holds"


def load(dir_):
    recs = {}
    for f in glob.glob(os.path.join(dir_, "*.json")):
        r = json.load(open(f))
        if not isinstance(r, dict) or "arch" not in r:
            continue                      # evidence files etc.
        key = (r["arch"], r["shape"],
               "multi" if r["mesh"].startswith("2x") else "single",
               "pipeline" if "pipeline" in os.path.basename(f) else "flat")
        recs[key] = r
    return recs


def main(dir_):
    recs = load(dir_)

    print("### Dry-run summary (single pod 16x16 = 256 chips; "
          "multi-pod 2x16x16 = 512 chips)\n")
    print("| arch | shape | 16x16 | 2x16x16 | compile(s/m) | "
          "args bytes/dev | temp bytes/dev |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = recs.get((a, s, "single", "flat"))
            r2 = recs.get((a, s, "multi", "flat"))
            if r1 is None and r2 is None:
                continue

            def st(r):
                if r is None:
                    return "(pending)"
                return {"ok": "ok", "skip": "skip*", "error": "ERROR"}[r["status"]]
            mem = (r1 or {}).get("memory") or {}
            arg_b = mem.get("argument_bytes")
            tmp_b = mem.get("temp_bytes")
            comp = (f"{(r1 or {}).get('compile_seconds', '-')}/"
                    f"{(r2 or {}).get('compile_seconds', '-')}")
            print(f"| {a} | {s} | {st(r1)} | {st(r2)} | {comp} | "
                  f"{fmt_b(arg_b)} | {fmt_b(tmp_b)} |")
    print("\n`skip*` = documented long_500k skip for pure full-attention "
          "archs (DESIGN.md §Arch-applicability).\n")

    print("### Roofline (single-pod 16x16, per chip: 197 TF bf16, "
          "819 GB/s HBM, 50 GB/s link)\n")
    print("| arch | shape | t_compute | t_memory | t_collective | "
          "bottleneck | MODEL/HLO flops | note |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "single", "flat"))
            if r is None or r["status"] != "ok":
                continue
            print(f"| {a} | {s} | {fmt_s(r['t_compute'])} | "
                  f"{fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} | "
                  f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
                  f"{improvement_hint(r)} |")

    print("\n### Collective traffic detail (per device, single-pod)\n")
    print("| arch | shape | all-gather | all-reduce | reduce-scatter | "
          "all-to-all | permute | wire total |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "single", "flat"))
            if r is None or r["status"] != "ok":
                continue
            c = r["collective_bytes_per_device"]
            w = r.get("collective_wire_bytes_per_device", {})
            print(f"| {a} | {s} | {fmt_b(c['all-gather'])} | "
                  f"{fmt_b(c['all-reduce'])} | {fmt_b(c['reduce-scatter'])} | "
                  f"{fmt_b(c['all-to-all'])} | "
                  f"{fmt_b(c['collective-permute'])} | "
                  f"{fmt_b(sum(w.values()) if w else None)} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
