"""Per-cell (arch x shape x mesh) step functions and ShapeDtypeStruct inputs.

``input_specs`` follows the shannon/kernels pattern: weak-type-correct,
shardable stand-ins with NO device allocation — decode caches for 500k-token
sequences are described, never materialized.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data.pipeline import batch_specs
from repro.models import lm
from repro.models.config import ArchConfig, ShapeConfig, SHAPES_BY_NAME
from repro.models.params import sharding_rules
from repro.optim import AdamWConfig, adamw_update, opt_meta
from repro.parallel import make_rules, logical_shardings, sanitized_shardings
from repro.models.params import abstract_tree, pspec_tree


@dataclasses.dataclass
class Cell:
    arch: ArchConfig
    shape: ShapeConfig
    kind: str                       # train | prefill | decode
    fn: Any
    args: Tuple
    in_shardings: Tuple
    donate: Tuple[int, ...]
    skip_reason: Optional[str] = None


def _axis_size(mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        s = 1
        for a in ax:
            s *= mesh.shape[a]
        return s
    return mesh.shape[ax]


def _tp_for(dim: int, tp: Optional[str], mesh) -> Optional[str]:
    """Shard dim over tp only if it divides evenly."""
    if tp is None:
        return None
    return tp if dim % mesh.shape[tp] == 0 and dim >= mesh.shape[tp] else None


def batch_abstract(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    st = 1 if shape.kind == "decode" else s
    out: Dict[str, Any] = {}
    if cfg.frontend:
        out["embeds"] = jax.ShapeDtypeStruct((b, st, cfg.d_model), jnp.float32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, st), jnp.int32)
    if shape.kind in ("train", "prefill"):
        out["labels"] = jax.ShapeDtypeStruct((b, st), jnp.int32)
        if cfg.rope == "mrope":
            out["positions"] = jax.ShapeDtypeStruct((3, b, st), jnp.int32)
    return out


def cache_pspecs(cfg: ArchConfig, batch: int, mesh, rules) -> Dict[str, Any]:
    """PartitionSpec tree matching lm.init_cache's structure.

    Batch >= dp: shard batch over dp (throughput decode).  Batch < dp (the
    long_500k single-sequence cell): shard the SEQUENCE of attention caches /
    history buffers over dp instead (flash-decoding layout), and state dims
    over tp.
    """
    dp, tp = rules.get("dp"), rules.get("tp")
    dpn = _axis_size(mesh, dp)
    shard_b = batch % dpn == 0 and batch >= dpn
    bax = dp if shard_b else None
    sax = None if shard_b else dp
    kv, hd = cfg.num_kv_heads, cfg.hd

    segs = []
    for kind, count in cfg.resolved_segments():
        if kind in ("attn_mlp", "attn_moe", "shared_attn"):
            kvax = _tp_for(kv, tp, mesh)
            hax = None if kvax else _tp_for(hd, tp, mesh)
            spec = P(None, bax, sax, kvax, hax)
            segs.append({"k": spec, "v": spec})
        elif kind == "mamba2":
            from repro.models.ssm import mamba2_dims
            di, nh, n = mamba2_dims(cfg)
            segs.append({
                "conv": P(None, bax, None, _tp_for(di + 2 * n, tp, mesh)),
                "ssd": P(None, bax, _tp_for(nh, tp, mesh), None, None)})
        elif kind == "mlstm":
            dh = 2 * cfg.d_model // cfg.num_heads
            hax = _tp_for(cfg.num_heads, tp, mesh)
            kax = None if hax else _tp_for(dh, tp, mesh)
            segs.append({"mlstm": P(None, bax, hax, kax, None)})
        elif kind == "slstm":
            dh = cfg.d_model // cfg.slstm_heads
            leaf = P(None, bax, None, _tp_for(dh, tp, mesh))
            segs.append({"slstm": (leaf, leaf, leaf, leaf)})
        elif kind == "fftconv_mlp":
            segs.append({"v_hist": P(None, bax, sax,
                                     _tp_for(cfg.d_model, tp, mesh))})
        else:
            segs.append({})
    return {"len": P(bax if shard_b else None), "segments": segs}


def build_cell(arch_name: str, shape_name: str, mesh,
               pipeline: bool = False) -> Cell:
    import os
    cfg = get_config(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    # REPRO_SERVE_WEIGHT_STATIONARY=1 flips inference cells to the
    # weight-stationary serving layout (§Perf hillclimb): bf16 params, MoE
    # d_ff sharded over data (no expert-weight gathers), FSDP disabled when
    # TP-sharded bf16 weights fit the 16 GB/chip HBM budget.  Default keeps
    # the FSDP-gathered f32 baseline the first sweep recorded.
    profile = "train"
    if shape.kind != "train" and os.environ.get(
            "REPRO_SERVE_WEIGHT_STATIONARY", "0") not in ("0", "", "false"):
        profile = "serve"
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16",
                                  reduce_dtype="bfloat16")
    if shape.kind == "train" and os.environ.get(
            "REPRO_REMAT_POLICY", "") in ("dots", "full"):
        cfg = dataclasses.replace(
            cfg, remat_policy=os.environ["REPRO_REMAT_POLICY"])
    rules = make_rules(mesh, pipeline_pods=pipeline, profile=profile)
    if profile == "serve":
        from repro.models.params import param_count
        pbytes = param_count(lm.model_meta(cfg)) * 2
        tp_size = mesh.shape.get("model", 1)
        if pbytes / tp_size <= 8e9 and "fsdp" in rules:
            del rules["fsdp"]          # weights TP-resident, no per-use gather

    if shape.kind == "decode" and shape.name == "long_500k" and not cfg.subquadratic:
        return Cell(cfg, shape, "skip", None, (), (), (),
                    skip_reason="pure full-attention arch: quadratic attention "
                    "at 500k context; skipped per assignment (see DESIGN.md)")

    meta = lm.model_meta(cfg)
    pspecs = logical_shardings(mesh, meta, rules)
    params_abs = abstract_tree(meta)
    batch_abs = batch_abstract(cfg, shape)
    raw_bspecs = batch_specs(cfg, shape, rules)
    # decode batches may omit labels/positions present in raw specs
    raw_bspecs = {k: raw_bspecs[k] for k in batch_abs}
    bspecs = sanitized_shardings(mesh, batch_abs, raw_bspecs)

    num_groups = _axis_size(mesh, rules.get("dp"))

    if shape.kind == "train":
        ocfg = AdamWConfig()
        om = opt_meta(meta)
        opt_abs = abstract_tree(om)

        from repro.parallel.pipelined_lm import (pipelined_loss_fn,
                                                 pipeline_param_shardings,
                                                 supports_pipeline)
        use_pipeline = (pipeline and "pod" in mesh.axis_names
                        and supports_pipeline(cfg))
        if use_pipeline:
            pspecs = pipeline_param_shardings(mesh, meta, rules)
            ospecs = {"mu": pipeline_param_shardings(mesh, om["mu"], rules),
                      "nu": pipeline_param_shardings(mesh, om["nu"], rules),
                      "step": logical_shardings(mesh, om["step"], rules)}
            loss_impl = lambda p, b: pipelined_loss_fn(p, cfg, b, mesh, rules)
        else:
            ospecs = logical_shardings(mesh, om, rules)
            loss_impl = lambda p, b: lm.loss_fn(p, cfg, b, num_groups)

        def train_step(params, opt_state, batch):
            with sharding_rules(mesh, rules):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_impl, has_aux=True)(params, batch)
            params, opt_state, om_ = adamw_update(ocfg, grads, params, opt_state)
            return params, opt_state, dict(metrics, loss=loss, **om_)

        return Cell(cfg, shape, "train", train_step,
                    (params_abs, opt_abs, batch_abs),
                    (pspecs, ospecs, bspecs), donate=(0, 1))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            with sharding_rules(mesh, rules):
                logits, _ = lm.forward(params, cfg, batch, num_groups)
            return logits[:, -1:, :]

        return Cell(cfg, shape, "prefill", prefill_step,
                    (params_abs, batch_abs), (pspecs, bspecs), donate=())

    # decode
    cache_abs = jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))
    cspecs = sanitized_shardings(
        mesh, cache_abs, cache_pspecs(cfg, shape.global_batch, mesh, rules))

    def serve_step(params, cache, batch):
        with sharding_rules(mesh, rules):
            return lm.decode_step(params, cfg, cache, batch, num_groups)

    return Cell(cfg, shape, "decode", serve_step,
                (params_abs, cache_abs, batch_abs),
                (pspecs, cspecs, bspecs), donate=(1,))
