"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (dryrun.py sets XLA_FLAGS before jax initializes).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """(16, 16) = 256 chips/pod (data, model), or (2, 16, 16) = 512 chips
    (pod, data, model) for the two-pod configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests, CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
