"""Training launcher.

On real hardware this runs the production mesh; on this CPU container use
--smoke (reduced config, local mesh over however many host devices exist).

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.config import ShapeConfig
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", choices=["local", "single", "multi"],
                    default="local")
    ap.add_argument("--data-axis", type=int, default=0,
                    help="local mesh data-parallel size (0 = all devices)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    if args.mesh == "local":
        n = len(jax.devices())
        data = args.data_axis or n
        mesh = make_local_mesh(data=data, model=n // data) if n > 1 else None
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    tr = Trainer(cfg, shape, mesh,
                 TrainerConfig(ckpt_dir=args.ckpt_dir,
                               ckpt_every=args.ckpt_every),
                 AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                             total_steps=args.steps))
    params, _, history = tr.run(args.steps)
    print(json.dumps({"first_loss": history[0]["loss"],
                      "last_loss": history[-1]["loss"],
                      "steps": len(history),
                      "straggler_events": len(tr.straggler_events)}, indent=1))


if __name__ == "__main__":
    main()
