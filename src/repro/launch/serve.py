"""Serving launcher: batched prefill + decode loop with a request queue.

Continuous-batching-lite: a fixed decode batch; finished sequences (EOS or
length budget) are refilled from the pending queue between steps, which is
the structure a production scheduler (vLLM-style) needs — admission control
and KV reuse slot in behind ``ServeLoop.step``.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --requests 16 --batch 4 --max-new 32
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.models.config import ShapeConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (S,) int32
    max_new: int
    out: Optional[List[int]] = None


class ServeLoop:
    def __init__(self, cfg, batch: int, max_len: int, seed: int = 0,
                 prompt_bucket: int = 8):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        recurrent = any(k in ("mamba2", "mlstm", "slstm", "fftconv_mlp")
                        for k, _ in cfg.resolved_segments())
        # recurrent state would absorb pad tokens — exact lengths for those
        # (attention caches mask pads via "len", so buckets are safe there)
        self.prompt_bucket = 1 if recurrent else prompt_bucket
        self.params = lm.init_params(cfg, jax.random.key(seed))
        self.cache = lm.init_cache(cfg, batch, max_len)
        self.slots: List[Optional[Request]] = [None] * batch
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._step = jax.jit(
            lambda p, c, b: lm.decode_step(p, cfg, c, b))
        # ONE forward pass per admitted prompt (bucketed lengths), its
        # single-sequence cache merged into the batch cache at the slot
        self._prefill = jax.jit(
            lambda p, b, li: lm.prefill(p, cfg, b, max_len=max_len,
                                        last_index=li))
        self._merge = jax.jit(self._merge_impl)

    @staticmethod
    def _merge_impl(cache, c1, i, true_len):
        segs = jax.tree_util.tree_map(
            lambda big, one: jax.lax.dynamic_update_slice_in_dim(
                big, one.astype(big.dtype), i, axis=1),
            cache["segments"], c1["segments"])
        return {"len": cache["len"].at[i].set(true_len), "segments": segs}

    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    def _admit(self):
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                n = len(req.prompt)
                bucket = -(-n // self.prompt_bucket) * self.prompt_bucket
                prompt = np.zeros((1, bucket), np.int32)
                prompt[0, :n] = req.prompt
                logits, c1 = self._prefill(
                    self.params, {"tokens": jnp.asarray(prompt)},
                    jnp.asarray([n - 1]))
                # cache positions n..bucket-1 hold padding but "len"=n masks
                # them out of attention (recurrent archs use exact buckets)
                self.cache = self._merge(self.cache, c1, i, n)
                first = int(np.argmax(np.asarray(logits)[0, 0]))
                req.out.append(first)              # token #1 from prefill
                req._last = first
                if len(req.out) >= req.max_new:
                    self.done.append(req)
                    self.slots[i] = None

    def step(self):
        self._admit()
        tok = np.zeros((self.batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None:
                tok[i, 0] = req._last
        logits, self.cache = self._step(self.params, self.cache,
                                        {"tokens": jnp.asarray(tok)})
        lg = np.asarray(logits)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            nxt = int(np.argmax(lg[i, 0]))
            req.out.append(nxt)
            req._last = nxt
            if len(req.out) >= req.max_new:
                self.done.append(req)
                self.slots[i] = None

    def drain(self):
        while self.queue or any(s is not None for s in self.slots):
            self.step()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    loop = ServeLoop(cfg, args.batch, args.max_len)
    t0 = time.perf_counter()
    for r in range(args.requests):
        loop.submit(Request(r, rng.integers(
            0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            args.max_new))
    loop.drain()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in loop.done)
    print(json.dumps({"requests": len(loop.done),
                      "generated_tokens": toks,
                      "tok_per_s": round(toks / dt, 1)}, indent=1))


if __name__ == "__main__":
    main()
