import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

The two lines above run before ANY other import (jax locks the device count
on first init).  Everything below is ordinary code.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCH_IDS, ALIASES, get_config          # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.launch.specs import build_cell                        # noqa: E402
from repro.models.config import SHAPES                           # noqa: E402

# ---------------------------------------------------------------------------
# hardware constants (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
LINK_BW = 50e9                    # bytes/s per ICI link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\])\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}|replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 1
    if m.group(1) is not None:
        first = m.group(1).split("}")[0]
        return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    return int(m.group(3))


def parse_collectives(hlo_text: str, with_wire: bool = False):
    """Per-device operand bytes of every collective, bucketed by op kind.

    ``with_wire`` additionally returns per-device BYTES ON THE LINK, which is
    what distinguishes e.g. AGAS-style all-gather (receives (P-1)/P of the
    full result) from an all_to_all moving the same operand:
      all-gather:      result - operand          (ring receive)
      all-reduce:      2 * operand * (P-1)/P     (reduce-scatter + gather)
      reduce-scatter:  operand * (P-1)/P
      all-to-all:      operand * (P-1)/P
      collective-permute: operand
    """
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    wire = dict.fromkeys(out, 0.0)
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        if "-done" in line.split("=")[-1][:60]:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        typestr, op = m.group(1), m.group(2)
        result_bytes = _shape_bytes(typestr)
        g = max(_group_size(line), 1)
        frac = (g - 1) / g
        if op == "all-gather":
            operand = result_bytes / g
            w = result_bytes - operand
        elif op == "reduce-scatter":
            operand = result_bytes * g
            w = operand * frac
        elif op == "all-reduce":
            operand = result_bytes
            w = 2 * operand * frac
        elif op == "all-to-all":
            operand = result_bytes
            w = operand * frac
        else:                       # collective-permute
            operand = result_bytes
            w = operand
        out[op] += operand
        wire[op] += w
        counts[op] += 1
    if with_wire:
        return out, counts, wire
    return out, counts


def inner_scan_flops_correction(cfg, shape) -> float:
    """Flops hidden from HloCostAnalysis by ROLLED inner scans (flash-attn KV
    blocks, chunked-GLA chunks, sLSTM time steps), added analytically.

    REPRO_SCAN_UNROLL only unrolls the LAYER loop; inner loops stay rolled so
    cost analysis sees 1/n_iters of their flops.  We add the missing
    (n-1)/n portion.  Train steps multiply by 4 (forward + remat-recompute +
    ~2x backward); prefill by 1.  Decode paths have no inner scans.
    Residual error after correction: <1% (chunk boundary terms).
    """
    if shape.kind == "decode":
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    mult = 4.0 if shape.kind == "train" else 1.0
    total = 0.0
    for kind, count in cfg.resolved_segments():
        if kind in ("attn_mlp", "attn_moe", "shared_attn"):
            block_kv = min(1024, s)
            nkv = max(s // block_kv, 1)
            fwd = 4.0 * b * s * s * cfg.num_heads * cfg.hd   # qk + pv MACs*2
            total += count * fwd * (nkv - 1) / nkv
        elif kind in ("mamba2", "mlstm"):
            q = 128
            nc = max(s // q, 1)
            if kind == "mamba2":
                di = cfg.ssm_expand * cfg.d_model
                h = di // cfg.ssm_head_dim
                dk, dv = cfg.ssm_state, cfg.ssm_head_dim
            else:
                h = cfg.num_heads
                dk = 2 * cfg.d_model // h
                dv = dk + 1
            # intra-chunk scores+out (2 MACs->flops each) + state update/carry
            fwd = 2.0 * b * s * h * (q * (dk + dv) + 2.0 * dk * dv)
            total += count * fwd * (nc - 1) / nc
        elif kind == "slstm":
            dh = cfg.d_model // cfg.slstm_heads
            fwd = 2.0 * b * s * 4.0 * cfg.slstm_heads * dh * dh
            total += count * fwd * (s - 1) / s
    return total * mult


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode counts one token/seq."""
    from repro.models import lm as _lm
    from repro.models.params import param_count
    total = param_count(_lm.model_meta(cfg))
    if cfg.num_experts:
        # non-active experts don't contribute: scale expert params by k/E
        from repro.models import blocks as _b
        active = total
        expert_fraction = (cfg.num_experts - cfg.top_k) / cfg.num_experts
        # expert params = 3 * d * ff * E per layer
        ep = 3 * cfg.d_model * cfg.d_ff * cfg.num_experts * cfg.num_layers
        active = total - ep * expert_fraction
        total = active
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * total * tokens


def _fwd_calibration(arch: str, shape_name: str, mesh):
    """Layer-body flop/collective calibration for train cells whose FULLY
    unrolled backward graph is too expensive to compile (deep recurrent
    stacks: 48-layer xLSTM, 81-layer zamba2).

    Compiles the rolled and the unrolled FORWARD pass (no autodiff — cheap),
    takes the delta (= per-layer body costs hidden by the rolled loop) and
    scales it: x4 for flops (fwd + remat recompute + ~2x bwd), x3 for
    collective bytes (FSDP gather in fwd, re-gather in remat, grad
    reduce-scatter).  Returned deltas are ADDED to the rolled train-step
    measurement.  Documented in EXPERIMENTS.md accounting notes.
    """
    from repro.configs import get_config
    from repro.models import lm as _lm
    from repro.models.params import abstract_tree, sharding_rules
    from repro.parallel import logical_shardings, make_rules
    from repro.launch.specs import batch_abstract
    from repro.data.pipeline import batch_specs
    from repro.parallel import sanitized_shardings
    from repro.models.config import SHAPES_BY_NAME

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    rules = make_rules(mesh)
    meta = _lm.model_meta(cfg)
    pspecs = logical_shardings(mesh, meta, rules)
    params_abs = abstract_tree(meta)
    batch_abs = batch_abstract(cfg, shape)
    raw_bspecs = {k: batch_specs(cfg, shape, rules)[k] for k in batch_abs}
    bspecs = sanitized_shardings(mesh, batch_abs, raw_bspecs)

    def fwd(params, batch):
        with sharding_rules(mesh, rules):
            return _lm.loss_fn(params, cfg, batch)[0]

    out = {}
    for mode in ("0", "1"):
        os.environ["REPRO_SCAN_UNROLL"] = mode
        with mesh:
            comp = jax.jit(fwd, in_shardings=(pspecs, bspecs)).lower(
                params_abs, batch_abs).compile()
        cost = comp.cost_analysis() or {}
        coll, _, wire = parse_collectives(comp.as_text(), with_wire=True)
        out[mode] = (float(cost.get("flops", 0.0)),
                     float(cost.get("bytes accessed", 0.0)),
                     coll, wire)
    os.environ["REPRO_SCAN_UNROLL"] = "0"
    d_flops = max(out["1"][0] - out["0"][0], 0.0)
    d_bytes = max(out["1"][1] - out["0"][1], 0.0)
    d_coll = {k: max(out["1"][2][k] - out["0"][2][k], 0.0) for k in out["1"][2]}
    d_wire = {k: max(out["1"][3][k] - out["0"][3][k], 0.0) for k in out["1"][3]}
    return d_flops, d_bytes, d_coll, d_wire


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             pipeline: bool = False, unroll_mode: str = "env"):
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cell = build_cell(arch, shape_name, mesh, pipeline=pipeline)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "chips": chips, "kind": cell.kind}
    if cell.skip_reason:
        rec["status"] = "skip"
        rec["reason"] = cell.skip_reason
        return rec

    calib = None
    prev_env = os.environ.get("REPRO_SCAN_UNROLL")
    if unroll_mode == "fwd" and cell.kind == "train":
        calib = _fwd_calibration(arch, shape_name, mesh)
        os.environ["REPRO_SCAN_UNROLL"] = "0"   # rolled full train step

    t0 = time.perf_counter()
    try:
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.args)
            compiled = lowered.compile()
    finally:
        if prev_env is None:
            os.environ.pop("REPRO_SCAN_UNROLL", None)
        else:
            os.environ["REPRO_SCAN_UNROLL"] = prev_env
    rec["compile_seconds"] = round(time.perf_counter() - t0, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    cost = compiled.cost_analysis() or {}
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    rec["hlo_flops_per_device"] = flops_dev
    rec["hlo_bytes_per_device"] = bytes_dev

    text = compiled.as_text()
    coll, counts, wire = parse_collectives(text, with_wire=True)
    if calib is not None:
        d_flops, d_bytes, d_coll, d_wire = calib
        rec["fwd_calibration"] = {"d_flops": d_flops, "d_bytes": d_bytes}
        flops_dev += 4.0 * d_flops
        bytes_dev += 4.0 * d_bytes
        rec["hlo_flops_per_device"] = flops_dev
        rec["hlo_bytes_per_device"] = bytes_dev
        coll = {k: coll[k] + 3.0 * d_coll[k] for k in coll}
        wire = {k: wire[k] + 3.0 * d_wire[k] for k in wire}
    rec["collective_bytes_per_device"] = coll
    rec["collective_counts"] = counts
    rec["collective_wire_bytes_per_device"] = wire
    rec["t_collective_wire"] = sum(wire.values()) / LINK_BW
    coll_total = sum(coll.values())

    corr = inner_scan_flops_correction(cell.arch, cell.shape) / chips
    rec["inner_scan_flops_correction_per_device"] = corr
    flops_dev += corr

    # roofline terms (seconds)
    peak = PEAK_FLOPS_BF16
    rec["t_compute"] = flops_dev / peak
    rec["t_memory"] = bytes_dev / HBM_BW
    rec["t_collective"] = coll_total / LINK_BW
    terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
             "collective": rec["t_collective"]}
    rec["bottleneck"] = max(terms, key=terms.get)

    mf = model_flops(cell.arch, cell.shape)
    rec["model_flops_total"] = mf
    rec["model_flops_per_device"] = mf / chips
    rec["useful_flops_ratio"] = (mf / chips) / flops_dev if flops_dev else 0.0
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--pipeline", action="store_true",
                    help="pod-axis pipeline parallelism (multi-pod only)")
    ap.add_argument("--unroll-mode", choices=["env", "fwd"], default="env",
                    help="'fwd': rolled train step + forward-unroll flop "
                         "calibration (deep recurrent stacks)")
    ap.add_argument("--out", default=None, help="JSON output directory")
    args = ap.parse_args()

    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in SHAPES] if args.all or not args.shape \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, mp, pipeline=args.pipeline,
                                   unroll_mode=args.unroll_mode)
                except Exception:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error",
                           "error": traceback.format_exc(limit=20)}
                results.append(rec)
                if rec["status"] == "ok":
                    print(f"[ok]   {tag}: compile={rec['compile_seconds']}s "
                          f"flops/dev={rec['hlo_flops_per_device']:.3e} "
                          f"coll/dev={sum(rec['collective_bytes_per_device'].values()):.3e}B "
                          f"bottleneck={rec['bottleneck']}", flush=True)
                elif rec["status"] == "skip":
                    print(f"[skip] {tag}: {rec['reason']}", flush=True)
                else:
                    print(f"[ERR]  {tag}:\n{rec['error']}", flush=True)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fn = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                    fn += "_pipeline" if args.pipeline else ""
                    with open(os.path.join(args.out, fn + ".json"), "w") as f:
                        json.dump(rec, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} documented skips, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
