"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242;
unverified].

Block layout: 81 blocks total — (6 Mamba2 + 1 shared-attention) x 11 + 4
Mamba2.  The shared attention+MLP block reuses ONE parameter set at all 11
occurrences (the Zamba weight-sharing trick); each occurrence owns its KV
cache.  Mamba2 backbone => sub-quadratic, runs the long_500k cell.
"""

import dataclasses

from repro.models.config import ArchConfig

_SEGMENTS = (("mamba2", 6), ("shared_attn", 1)) * 11 + (("mamba2", 4),)

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    segments=_SEGMENTS,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    rope="standard", norm="rmsnorm", mlp_act="silu",
    subquadratic=True,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=512, ssm_state=16, ssm_head_dim=8, num_layers=7,
    segments=(("mamba2", 3), ("shared_attn", 1), ("mamba2", 3)),
    compute_dtype="float32")
