"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304
— non-parametric LN [arXiv:2402.00838; hf]."""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=50304,
    rope="standard", norm="nonparam_ln", mlp_act="silu",
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, compute_dtype="float32")
