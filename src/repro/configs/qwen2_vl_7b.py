"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064
— M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only (per assignment): the vision frontend is a stub;
``input_specs()`` provides precomputed patch embeddings plus the (3, B, S)
temporal/height/width M-RoPE position streams.
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    rope="mrope", mrope_sections=(16, 24, 24), qkv_bias=True,
    norm="rmsnorm", mlp_act="silu",
    frontend="vision",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=56, num_heads=4, num_kv_heads=2,
    d_ff=112, vocab_size=512, mrope_sections=(3, 2, 2),
    compute_dtype="float32")
