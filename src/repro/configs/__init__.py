"""Assigned-architecture registry: one module per architecture, exact pool
configs, plus reduced smoke variants and the FFT case-study configs."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

ARCH_IDS = [
    "granite_8b", "olmo_1b", "command_r_plus_104b", "granite_3_2b",
    "phi35_moe_42b", "dbrx_132b", "xlstm_1_3b", "zamba2_7b",
    "qwen2_vl_7b", "musicgen_large",
]

# public --arch aliases (hyphenated pool names) -> module ids
ALIASES = {
    "granite-8b": "granite_8b",
    "olmo-1b": "olmo_1b",
    "command-r-plus-104b": "command_r_plus_104b",
    "granite-3-2b": "granite_3_2b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "dbrx-132b": "dbrx_132b",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-7b": "zamba2_7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "musicgen-large": "musicgen_large",
}


def get_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
