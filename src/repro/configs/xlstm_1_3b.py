"""xlstm-1.3b [ssm]: 48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

Block layout: xLSTM[7:1] ratio — six repeats of (7 mLSTM + 1 sLSTM).
d_ff=0: mixers carry their own up/down projections (factor-2 for mLSTM).
Sub-quadratic (linear recurrence) => runs the long_500k cell.
"""

import dataclasses

from repro.models.config import ArchConfig

_SEGMENTS = (("mlstm", 7), ("slstm", 1)) * 6

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    segments=_SEGMENTS, slstm_heads=4,
    rope="none", norm="rmsnorm",
    subquadratic=True, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, d_model=64, num_heads=4, num_kv_heads=4, vocab_size=512,
    num_layers=4, segments=(("mlstm", 3), ("slstm", 1)),
    compute_dtype="float32")
