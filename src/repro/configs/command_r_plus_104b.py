"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000 — GQA, no-bias, parallel attn+FFN blocks
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000,
    rope="standard", norm="layernorm", mlp_act="silu",
    parallel_block=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=192, vocab_size=512, compute_dtype="float32")
