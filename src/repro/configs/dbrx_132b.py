"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained [hf:databricks/dbrx-base; unverified]."""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    num_experts=16, top_k=4,
    rope="standard", norm="layernorm", mlp_act="silu",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=144, vocab_size=512, num_experts=4, top_k=2,
    compute_dtype="float32")
