"""musicgen-large [audio]: 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only (per assignment): the EnCodec tokenizer frontend is a stub;
``input_specs()`` provides precomputed frame embeddings.  Sinusoidal
absolute positions (rope='none'), LayerNorm + GELU per the MusicGen
transformer.
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    rope="none", norm="layernorm", mlp_act="gelu",
    frontend="audio",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=128, compute_dtype="float32")
