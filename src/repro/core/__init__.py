"""The paper's contribution: planned, variant-swappable, distributed FFT."""

from . import algo, api, comm, compat, dfft, fftconv, plan, variants, wisdom
from .algo import fft, fft2, ifft, irfft, rfft, rfft2, to_complex, to_pair
from .api import (NdPlan, execute_nd, execute_nd_inverse, fftn, ifftn,
                  irfftn, plan_nd, rfftn)
from .comm import (COMM_BACKENDS, AgasBackend, CollectiveBackend, CommBackend,
                   PipelinedBackend, get_backend, measure_comm,
                   measure_comm_conv, measure_comm_factor1d,
                   measure_comm_gather, measure_comm_pencil,
                   measure_comm_pencil_nd, measure_comm_slab,
                   measure_comm_slab_nd, pad_to, plan_comm, plan_comm_conv,
                   plan_comm_factor1d, plan_comm_gather, plan_comm_pencil,
                   plan_comm_pencil_nd, plan_comm_slab_nd,
                   resolve_axis_backends)
from .dfft import (collect, distribute, fft2_slab, fft3_pencil, ifft2_slab,
                   ifft3_pencil, irfft3_pencil, rfft3_pencil)
from .fftconv import factor_split, fft_conv, fft_conv_seq_sharded
from .plan import CPU_LOCAL, TPU_V5E, Plan, Planner, execute, execute_inverse
from .variants import VARIANTS, run_variant
from .wisdom import WisdomStore

__all__ = [
    "algo", "api", "comm", "compat", "dfft", "fftconv", "plan", "variants",
    "wisdom",
    "fft", "ifft", "rfft", "irfft", "fft2", "rfft2",
    "to_pair", "to_complex",
    # the planned N-D front-end (use these; the *_slab/*_pencil entry
    # points below are deprecated shims)
    "NdPlan", "plan_nd", "execute_nd", "execute_nd_inverse",
    "fftn", "ifftn", "rfftn", "irfftn",
    "COMM_BACKENDS", "CommBackend", "CollectiveBackend", "PipelinedBackend",
    "AgasBackend", "get_backend", "resolve_axis_backends", "pad_to",
    "plan_comm", "plan_comm_slab_nd", "plan_comm_pencil",
    "plan_comm_pencil_nd", "plan_comm_conv", "plan_comm_factor1d",
    "plan_comm_gather",
    "measure_comm", "measure_comm_slab", "measure_comm_slab_nd",
    "measure_comm_pencil", "measure_comm_pencil_nd", "measure_comm_conv",
    "measure_comm_factor1d", "measure_comm_gather",
    "WisdomStore",
    "fft2_slab", "ifft2_slab",
    "fft3_pencil", "ifft3_pencil", "rfft3_pencil", "irfft3_pencil",
    "distribute", "collect",
    "factor_split", "fft_conv", "fft_conv_seq_sharded",
    "Plan", "Planner", "execute", "execute_inverse", "TPU_V5E", "CPU_LOCAL",
    "VARIANTS", "run_variant",
]
