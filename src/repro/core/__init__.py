"""The paper's contribution: planned, variant-swappable, distributed FFT."""

from . import algo, comm, compat, dfft, fftconv, plan, variants, wisdom
from .algo import fft, fft2, fftn, ifft, irfft, rfft, rfft2, to_complex, to_pair
from .comm import (COMM_BACKENDS, AgasBackend, CollectiveBackend, CommBackend,
                   PipelinedBackend, get_backend, measure_comm,
                   measure_comm_conv, measure_comm_gather, measure_comm_pencil,
                   measure_comm_slab, plan_comm, plan_comm_conv,
                   plan_comm_gather, plan_comm_pencil, resolve_axis_backends)
from .dfft import (fft2_slab, fft3_pencil, ifft2_slab, ifft3_pencil,
                   irfft3_pencil, rfft3_pencil)
from .fftconv import fft_conv, fft_conv_seq_sharded
from .plan import CPU_LOCAL, TPU_V5E, Plan, Planner, execute, execute_inverse
from .variants import VARIANTS, run_variant
from .wisdom import WisdomStore

__all__ = [
    "algo", "comm", "compat", "dfft", "fftconv", "plan", "variants", "wisdom",
    "fft", "ifft", "rfft", "irfft", "fft2", "rfft2", "fftn",
    "to_pair", "to_complex",
    "COMM_BACKENDS", "CommBackend", "CollectiveBackend", "PipelinedBackend",
    "AgasBackend", "get_backend", "resolve_axis_backends",
    "plan_comm", "plan_comm_pencil", "plan_comm_conv", "plan_comm_gather",
    "measure_comm", "measure_comm_slab", "measure_comm_pencil",
    "measure_comm_conv", "measure_comm_gather", "WisdomStore",
    "fft2_slab", "ifft2_slab",
    "fft3_pencil", "ifft3_pencil", "rfft3_pencil", "irfft3_pencil",
    "fft_conv", "fft_conv_seq_sharded",
    "Plan", "Planner", "execute", "execute_inverse", "TPU_V5E", "CPU_LOCAL",
    "VARIANTS", "run_variant",
]
