"""The paper's shared-memory 2D-FFT implementation variants (§3.3, Fig. 1).

Each variant computes the SAME transform — r2c 2D FFT of a real N x M matrix
(r2c along the contiguous rows, c2c along the columns) — with a different
task/synchronization structure.  The HPX concepts map to XLA as:

  HPX fine-grained task       ->  one ``lax.map`` chunk (task_size rows)
  future dependency chain     ->  per-chunk compute+scatter interleaving
  global sync barrier         ->  ``lax.optimization_barrier`` (forbids fusion
                                  across the barrier, forcing materialization
                                  exactly like a join on all futures)
  AGAS implicit data movement ->  gather through explicit global index arrays
  hpx::for_loop (bulk sync)   ->  whole-array ops inside one fused jit

The paper's finding — bulk-synchronous beats clever asynchrony because cache
behaviour dominates — is reproduced here as: chunked variants defeat XLA
fusion and add HBM round-trips; the barrier *placement* decides whether the
transpose reads or writes contiguously.

All variants return (re, im) of shape (N, M//2 + 1) and are verified
identical against numpy in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import algo
from .plan import Plan, Planner

Complex = algo.Complex

VARIANTS = ("future_naive", "future_opt", "future_sync", "future_agas", "for_loop")


def _row_plan(planner: Planner, m: int) -> Plan:
    return planner.plan(m, kind="r2c")


def _col_plan(planner: Planner, n: int) -> Plan:
    return planner.plan(n, kind="c2c")


def _barrier(*trees):
    """Global synchronization barrier: forces XLA to materialize operands and
    forbids fusion across it (the 'join all futures' of the paper)."""
    flat, treedef = jax.tree_util.tree_flatten(trees)
    flat = jax.lax.optimization_barrier(tuple(flat))
    out = jax.tree_util.tree_unflatten(treedef, list(flat))
    return out[0] if len(out) == 1 else out


# ---------------------------------------------------------------------------
# variant: for_loop — the paper's winner (bulk-synchronous, fully fused)
# ---------------------------------------------------------------------------


def fft2_for_loop(x: jax.Array, planner: Planner) -> Complex:
    """hpx::experimental::for_loop analogue: whole-array bulk stages inside a
    single jit; XLA fuses/fissions freely (the best 'cache schedule')."""
    from .plan import execute
    n, m = x.shape
    y = execute(_row_plan(planner, m), x)                       # r2c rows
    yt = (y[0].T, y[1].T)                                       # transpose
    z = execute(_col_plan(planner, n), yt)                      # c2c rows
    return z[0].T, z[1].T                                       # transpose back


# ---------------------------------------------------------------------------
# variant: future_sync — barrier after EVERY algorithmic step
# ---------------------------------------------------------------------------


def fft2_future_sync(x: jax.Array, planner: Planner) -> Complex:
    from .plan import execute
    n, m = x.shape
    y = execute(_row_plan(planner, m), x)
    y = _barrier(y)
    yt = (y[0].T, y[1].T)
    yt = _barrier(yt)
    z = execute(_col_plan(planner, n), yt)
    z = _barrier(z)
    return z[0].T, z[1].T


# ---------------------------------------------------------------------------
# chunked "futurized" variants — task_size rows per task
# ---------------------------------------------------------------------------


def _chunked_rfft(x: jax.Array, plan: Plan, task_size: int) -> Complex:
    """lax.map over row chunks = one HPX task per chunk."""
    from .plan import execute
    n, m = x.shape
    task_size = min(task_size, n)
    while n % task_size:
        task_size -= 1
    xc = x.reshape(n // task_size, task_size, m)
    re, im = jax.lax.map(lambda c: execute(plan, c), xc)
    return re.reshape(n, m // 2 + 1), im.reshape(n, m // 2 + 1)


def fft2_future_naive(x: jax.Array, planner: Planner, task_size: int = 8) -> Complex:
    """Naive futurization (paper: 'postpone or remove synchronization').

    Each FFT task's dependent transpose task immediately scatters its rows
    into the *columns* of the transposed buffer — non-contiguous writes, no
    barrier between FFT and transpose.  Mirrors the paper's cache-hostile
    read-side-optimal ordering.
    """
    from .plan import execute
    n, m = x.shape
    mh = m // 2 + 1
    task_size = max(1, min(task_size, n))
    while n % task_size:
        task_size -= 1
    n_tasks = n // task_size
    row_plan = _row_plan(planner, m)

    def task(carry, i):
        tre, tim = carry
        chunk = jax.lax.dynamic_slice_in_dim(x, i * task_size, task_size, 0)
        fre, fim = execute(row_plan, chunk)                     # FFT task
        # dependent transpose task: scatter rows into columns (strided writes)
        tre = jax.lax.dynamic_update_slice(tre, fre.T, (0, i * task_size))
        tim = jax.lax.dynamic_update_slice(tim, fim.T, (0, i * task_size))
        return (tre, tim), 0

    init = (jnp.zeros((mh, n), jnp.float32), jnp.zeros((mh, n), jnp.float32))
    (tre, tim), _ = jax.lax.scan(task, init, jnp.arange(n_tasks))
    z = execute(_col_plan(planner, n), (tre, tim))
    return z[0].T, z[1].T


def fft2_future_opt(x: jax.Array, planner: Planner, task_size: int = 8) -> Complex:
    """Optimized transpose (paper §3.2): the barrier is moved BEFORE the
    transpose, so transpose tasks WRITE contiguous memory (each task gathers
    strided reads but writes one contiguous row-block of the transposed
    buffer)."""
    from .plan import execute
    n, m = x.shape
    mh = m // 2 + 1
    y = _chunked_rfft(x, _row_plan(planner, m), task_size)
    y = _barrier(y)                                             # moved barrier
    ts = max(1, min(task_size, mh))
    while mh % ts:
        ts -= 1
    yc = (y[0].reshape(n, mh // ts, ts), y[1].reshape(n, mh // ts, ts))

    def transpose_task(j):
        # write-contiguous block (ts, n) of the transposed matrix
        return yc[0][:, j, :].T, yc[1][:, j, :].T

    tre, tim = jax.lax.map(transpose_task, jnp.arange(mh // ts))
    t = (tre.reshape(mh, n), tim.reshape(mh, n))
    z = execute(_col_plan(planner, n), t)
    return z[0].T, z[1].T


# ---------------------------------------------------------------------------
# variant: future_agas — implicit global-address-space data movement
# ---------------------------------------------------------------------------


def fft2_future_agas(x: jax.Array, planner: Planner) -> Complex:
    """AGAS analogue: data 'moves' by resolving global indices through an
    address table (gather), instead of a direct transpose copy.  The extra
    index arithmetic + gather is the measurable AGAS overhead of Fig. 1."""
    from .plan import execute
    n, m = x.shape
    mh = m // 2 + 1
    y = execute(_row_plan(planner, m), x)
    # global address table: flat_transposed[i] lives at flat[src[i]]
    src = (jnp.arange(mh * n, dtype=jnp.int32) % n) * mh \
        + (jnp.arange(mh * n, dtype=jnp.int32) // n)
    yt = (jnp.take(y[0].reshape(-1), src).reshape(mh, n),
          jnp.take(y[1].reshape(-1), src).reshape(mh, n))
    z = execute(_col_plan(planner, n), yt)
    dst = (jnp.arange(n * mh, dtype=jnp.int32) % mh) * n \
        + (jnp.arange(n * mh, dtype=jnp.int32) // mh)
    return (jnp.take(z[0].reshape(-1), dst).reshape(n, mh),
            jnp.take(z[1].reshape(-1), dst).reshape(n, mh))


# ---------------------------------------------------------------------------
# strided (no-transpose) column FFT — the paper's §3.2 'strided access' option
# ---------------------------------------------------------------------------


def fft2_strided(x: jax.Array, planner: Planner) -> Complex:
    """Keep row-major layout; run the second-dimension FFT with stride N
    (contract over the leading axis directly, no transpose)."""
    from .plan import execute
    n, m = x.shape
    y = execute(_row_plan(planner, m), x)                       # (n, mh)
    col_plan = _col_plan(planner, n)
    # contract the *leading* axis against the DFT chain: move axis without
    # materializing a transpose (XLA keeps the strided layout)
    yt = (jnp.moveaxis(y[0], 0, -1), jnp.moveaxis(y[1], 0, -1))
    z = execute(col_plan, yt)
    return jnp.moveaxis(z[0], -1, 0), jnp.moveaxis(z[1], -1, 0)


def run_variant(name: str, x: jax.Array, planner: Planner,
                task_size: int = 8) -> Complex:
    if name == "future_naive":
        return fft2_future_naive(x, planner, task_size)
    if name == "future_opt":
        return fft2_future_opt(x, planner, task_size)
    if name == "future_sync":
        return fft2_future_sync(x, planner)
    if name == "future_agas":
        return fft2_future_agas(x, planner)
    if name == "for_loop":
        return fft2_for_loop(x, planner)
    if name == "strided":
        return fft2_strided(x, planner)
    raise ValueError(f"unknown variant {name!r}; options: {VARIANTS + ('strided',)}")


# ---------------------------------------------------------------------------
# instrumented decomposition (paper Fig. 2): per-stage timings
# ---------------------------------------------------------------------------


def staged_for_loop(x: jax.Array, planner: Planner):
    """Return separately-jitted stages so benchmarks can time fft1 /
    transpose / fft2 / transpose-back independently (Fig. 2)."""
    from .plan import execute
    n, m = x.shape
    row_plan, col_plan = _row_plan(planner, m), _col_plan(planner, n)
    s1 = jax.jit(lambda a: execute(row_plan, a))
    s2 = jax.jit(lambda c: (c[0].T, c[1].T))
    s3 = jax.jit(lambda c: execute(col_plan, c))
    s4 = jax.jit(lambda c: (c[0].T, c[1].T))
    return [("fft_r2c_rows", s1), ("transpose", s2), ("fft_c2c_cols", s3),
            ("transpose_back", s4)]
