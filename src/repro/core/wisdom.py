"""Unified FFTW-style wisdom: one store for plan AND comm verdicts.

FFTW accumulates the results of expensive MEASURE-mode planning in
*wisdom* that can be exported, re-imported, and forgotten.  We extend the
idea to the paper's second expensive choice — the communication backend
(§5.3's parcelport swing) — by sharing a single JSON store between the two
autotuners, namespaced by key prefix:

* ``plan/...`` — 1D transform plans written by :class:`repro.core.plan.Planner`
  (key: ``plan/{n}/{kind}/b{log2-batch-bucket}/{mode}/{permuted}/{backends}``).
* ``comm/...`` — exchange-backend verdicts written by the
  :func:`repro.core.comm.measure_comm` family (key encodes decomposition,
  global shape, mesh shape, kind, and which mesh-axis exchange).
* ``dfft/...`` — N-D decomposition verdicts (local vs slab vs pencil, with
  mesh-axis assignment and resolved comm specs) written by
  :func:`repro.core.api.plan_nd`.

On-disk schema (one file, stable across both namespaces)::

    {"schema": "repro-wisdom", "version": 1, "entries": {key: record}}

The store is deliberately forgiving on load: a corrupt, empty, or
stale-schema file downgrades to an empty store with a ``UserWarning``
instead of crashing the planner (wisdom is a cache, never ground truth).
``export_wisdom`` / ``import_wisdom`` / ``forget_wisdom`` mirror FFTW's
``fftw_export_wisdom_to_string`` / ``fftw_import_wisdom_from_string`` /
``fftw_forget_wisdom``; exports are canonical (sorted keys) so an
export -> import -> export cycle is byte-identical.
"""

from __future__ import annotations

import json
import os
import warnings
from typing import Dict, Iterator, Optional

SCHEMA = "repro-wisdom"
VERSION = 1

PLAN_NS = "plan/"
COMM_NS = "comm/"
DFFT_NS = "dfft/"   # N-D decomposition verdicts (repro.core.api.plan_nd)


class WisdomStore:
    """Dict-of-records wisdom cache with optional JSON persistence.

    ``path=None`` keeps the store purely in-process.  With a path, every
    :meth:`put` persists atomically (tmp + rename), and construction loads
    whatever valid wisdom the file holds.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._entries: Dict[str, dict] = {}
        if path and os.path.exists(path):
            self._load(path)

    # -- persistence ---------------------------------------------------------

    def _load(self, path: str) -> None:
        try:
            with open(path) as f:
                raw = json.load(f)
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            warnings.warn(f"wisdom file {path!r} unreadable ({e}); "
                          "starting with empty wisdom")
            return
        if (not isinstance(raw, dict) or raw.get("schema") != SCHEMA
                or raw.get("version") != VERSION
                or not isinstance(raw.get("entries"), dict)):
            warnings.warn(f"wisdom file {path!r} has an unrecognized or stale "
                          f"schema (want {SCHEMA} v{VERSION}); starting with "
                          "empty wisdom")
            return
        self._entries = raw["entries"]

    def save(self, path: Optional[str] = None) -> None:
        path = path or self.path
        if not path:
            return
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.export_wisdom())
        os.replace(tmp, path)

    # -- mapping surface -----------------------------------------------------

    def get(self, key: str) -> Optional[dict]:
        return self._entries.get(key)

    def put(self, key: str, record: dict) -> None:
        self._entries[key] = record
        self.save()

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self, prefix: str = "") -> Iterator[str]:
        return (k for k in sorted(self._entries) if k.startswith(prefix))

    # -- FFTW-style API ------------------------------------------------------

    def export_wisdom(self) -> str:
        """Serialize to the canonical JSON text (sorted keys, so repeated
        exports of equal stores are byte-identical)."""
        return json.dumps({"schema": SCHEMA, "version": VERSION,
                           "entries": self._entries},
                          indent=1, sort_keys=True)

    def import_wisdom(self, text: str, replace: bool = False) -> int:
        """Merge (or, with ``replace``, adopt) wisdom from an exported
        string.  Returns the number of entries imported.  Unlike file
        loading, a malformed string raises — the caller asked for exactly
        this wisdom, so silence would hide a real bug."""
        raw = json.loads(text)
        if (not isinstance(raw, dict) or raw.get("schema") != SCHEMA
                or raw.get("version") != VERSION
                or not isinstance(raw.get("entries"), dict)):
            raise ValueError(
                f"not a {SCHEMA} v{VERSION} wisdom string")
        if replace:
            self._entries = {}
        self._entries.update(raw["entries"])
        self.save()
        return len(raw["entries"])

    def forget_wisdom(self, prefix: str = "") -> int:
        """Drop all entries (or just those under ``prefix``, e.g. ``comm/``).
        Returns the number forgotten."""
        if not prefix:
            n, self._entries = len(self._entries), {}
        else:
            victims = [k for k in self._entries if k.startswith(prefix)]
            for k in victims:
                del self._entries[k]
            n = len(victims)
        self.save()
        return n

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"WisdomStore(path={self.path!r}, "
                f"entries={len(self._entries)})")


def batch_bucket(batch: int) -> int:
    """log2 bucket for plan keys: batches 4..7 share bucket 2, 4096..8191
    share bucket 12.  Keeps wisdom reuse honest — a plan measured at
    batch=1 must not silently serve batch=4096."""
    return max(int(batch), 1).bit_length() - 1
