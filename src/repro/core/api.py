"""One planned N-D transform front-end: ``plan_nd`` + the ``fftn`` family.

The paper's central lesson is that the *plan* — not clever asynchrony —
decides FFT performance.  Our distributed layer used to make the biggest
planning decision (slab vs pencil vs purely local, and how to pad/batch) by
forcing the caller to pick among six shape-specific entry points.  This
module hides that behind FFTW's ``plan_many``/guru idea: one planner that
scores every decomposition the mesh supports and returns a pure-data
:class:`NdPlan` recipe, plus thin ``fftn``/``ifftn``/``rfftn``/``irfftn``
conveniences that execute it.

Decompositions scored (both of the paper's planning modes):

* **local**    — single-device planned execution (no mesh, or the exchange
  cost outweighs the speedup; on a mesh the model charges one gather).
* **slab**     — 1D decomposition over one mesh axis (ndim >= 2), including
  which mesh axis (assignment matters: it sets the padding).
* **pencil**   — P3DFFT-style multi-axis decomposition (ndim >= 3), over
  every ordered tuple of 2..ndim-1 mesh axes: the leading transform axes
  are sharded and one exchange per adjacent pair walks the chain.
* **factor1d** — distributed 1D c2c via the ``fft_conv`` factor-split
  algorithm (the length-N signal viewed as an (n1, n2) matrix, three
  exchanges), whenever ``repro.core.fftconv.factor_split`` finds a split.

The planner also decides the OUTPUT LAYOUT: ``output_layout="transposed"``
asks for the spectrum sharded over the last transform axis instead of the
first, which lets the slab executor skip its second exchange entirely (and
``ifftn`` invert the transposed spectrum with a single exchange, no
re-shuffle).  Values stay at their natural numpy index positions either
way — only the sharding differs — so ``NdPlan.crop`` is unchanged.

``mode="estimate"`` scores candidates with the roofline model extended from
:mod:`repro.core.plan` / :mod:`repro.core.comm` (compute + HBM + wire bytes
+ a per-collective latency charge).  ``mode="measured"`` additionally
compiles and times the finalists on the LIVE mesh — FFTW MEASURE applied to
the decomposition choice — reusing the ``measure_comm_*`` autotuners for
each finalist's exchanges.  Verdicts are cached under the ``dfft/*``
namespace of the unified wisdom store, next to the ``plan/*`` and ``comm/*``
entries, so a given (shape, mesh, kind, mode, comm) decision is made once
per process — and once per *machine* with a wisdom file.

The executors live in :mod:`repro.core.dfft`; this module only plans,
dispatches, and crops (``NdPlan.crop`` recovers the exact transform from
the collective-padded layout, including mixed-radix mesh shapes).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import algo, dfft
from .comm import (_normalize_axis_specs, _time_callable, fac_sum,
                   measure_comm_factor1d, measure_comm_pencil_nd,
                   measure_comm_slab_nd, pad_to, plan_comm_factor1d,
                   plan_comm_pencil_nd, plan_comm_slab_nd)
from .fftconv import factor_split
from .plan import Planner, execute, execute_inverse

Complex = algo.Complex

__all__ = ["NdPlan", "plan_nd", "execute_nd", "execute_nd_inverse",
           "fftn", "ifftn", "rfftn", "irfftn", "PLAN_ND_STATS",
           "COLLECTIVE_LAT"]

DECOMPS = ("local", "slab", "pencil", "factor1d")
OUTPUT_LAYOUTS = ("natural", "transposed")

#: per-collective latency charge in the decomposition roofline (seconds).
#: This is what makes small transforms stay local: two exchanges cost more
#: than the whole FFT until the wire/compute terms dominate.
COLLECTIVE_LAT = 2e-5

#: whole-transform timing probes actually executed by ``mode="measured"``;
#: tests snapshot this to prove wisdom hits re-measure nothing.
PLAN_ND_STATS = {"timed": 0}


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NdPlan:
    """A pure-data recipe for one N-D (possibly distributed) transform.

    ``shape`` is the transform shape (the trailing axes of the input; any
    leading axes are batch).  ``mesh_axes``/``mesh_shape`` name the mesh
    axes the decomposition uses, in decomposition order; ``comm`` holds one
    RESOLVED exchange spec per mesh axis (never ``"auto"``/``"measure"`` —
    those are resolved at planning time).  ``output_layout="transposed"``
    leaves the spectrum sharded over the LAST transform axis (the slab
    executor skips its restore exchange; values keep natural positions).
    ``factors`` is the (n1, n2) split of the ``factor1d`` decomposition.
    """

    shape: Tuple[int, ...]
    kind: str                            # "c2c" | "r2c"
    decomp: str                          # one of DECOMPS
    mesh_axes: Tuple[str, ...] = ()
    mesh_shape: Tuple[int, ...] = ()
    comm: Tuple = ()
    mode: str = "estimate"
    est_cost: float = 0.0
    measured_cost: float = -1.0
    output_layout: str = "natural"       # "natural" | "transposed"
    factors: Tuple[int, ...] = ()        # factor1d: the (n1, n2) split

    # -- padded layout (the shared pad-and-crop convention) -----------------

    @property
    def spectrum_shape(self) -> Tuple[int, ...]:
        """Exact transform output shape (``numpy.fft.fftn``/``rfftn``)."""
        if self.kind == "r2c":
            return self.shape[:-1] + (self.shape[-1] // 2 + 1,)
        return self.shape

    @property
    def padded_spectrum_shape(self) -> Tuple[int, ...]:
        """Spectrum shape with the collective-divisibility padding the
        executors produce (equal to ``spectrum_shape`` for local plans)."""
        s, sp = self.shape, self.spectrum_shape
        if self.decomp == "slab":
            (p,) = self.mesh_shape
            return (pad_to(s[0], p),) + s[1:-1] + (pad_to(sp[-1], p),)
        if self.decomp == "pencil":
            ps, k = self.mesh_shape, len(self.mesh_shape)
            # axis j (0 < j < k) is input-sharded over p_j and
            # exchange-split over p_{j-1}, so its padding must divide both
            # communicators; unsharded middle axes stay unpadded
            return ((pad_to(s[0], ps[0]),)
                    + tuple(pad_to(s[j], math.lcm(ps[j - 1], ps[j]))
                            for j in range(1, k))
                    + s[k:-1] + (pad_to(sp[-1], ps[-1]),))
        return sp

    @property
    def padded_input_shape(self) -> Tuple[int, ...]:
        """Input transform-shape after the executors' zero-padding of the
        sharded axes (the last axis is always fully local going in)."""
        return self.padded_spectrum_shape[:-1] + (self.shape[-1],)

    @property
    def crop(self) -> Tuple[slice, ...]:
        """Slices recovering the exact spectrum from the padded layout:
        ``padded[(..., *plan.crop)] == numpy`` result.  This is THE cropping
        contract — callers never hard-code the padded column count."""
        return tuple(slice(0, n) for n in self.spectrum_shape)

    def crop_pair(self, c: Complex) -> Complex:
        """Apply :attr:`crop` to an (re, im) pair (batch dims untouched)."""
        idx = (Ellipsis,) + self.crop
        return c[0][idx], c[1][idx]


# ---------------------------------------------------------------------------
# the decomposition roofline (ESTIMATE mode)
# ---------------------------------------------------------------------------


def _estimate_nd(plan: NdPlan, hw, on_mesh: bool) -> float:
    """Roofline seconds for one execution of ``plan`` on ``hw``.

    Extends the 1D model of :class:`repro.core.plan.Planner` and the
    exchange model of :func:`repro.core.comm.plan_comm`: per-device compute
    is max(flops, HBM passes), each redistribution charges its wire bytes
    through one link plus ``COLLECTIVE_LAT``, and a *local* plan on a live
    mesh charges one gather of the whole array (the data is distributed;
    somebody has to move it).  Padding waste is priced in by using the
    padded shapes, which is what makes mesh-axis assignment non-trivial.
    """
    d = len(plan.shape)
    padded = plan.padded_spectrum_shape
    elems = float(np.prod(padded))
    bytes_pair = elems * 8.0                       # (re, im) f32
    if plan.decomp == "factor1d":                  # two planned 1D stages
        stage_macs = fac_sum(plan.factors[0]) + fac_sum(plan.factors[1])
    else:
        stage_macs = sum(fac_sum(n) for n in plan.shape)
    flops = 8.0 * elems * stage_macs
    devices = max(int(np.prod(plan.mesh_shape or (1,))), 1)
    t_comp = max(flops / hw.flops,
                 (d + 1) * bytes_pair / hw.hbm_bw) / devices
    t_comm = 0.0
    if plan.decomp == "local":
        if on_mesh:
            t_comm = bytes_pair / hw.link_bw + COLLECTIVE_LAT
    elif plan.decomp == "slab":
        (p,) = plan.mesh_shape
        wire = (p - 1) / p * (bytes_pair / p)
        # a transposed output layout skips the restore exchange entirely
        n_exchanges = 1.0 if plan.output_layout == "transposed" else 2.0
        t_comm = n_exchanges * (wire / hw.link_bw + COLLECTIVE_LAT)
    elif plan.decomp == "factor1d":
        (p,) = plan.mesh_shape
        wire = (p - 1) / p * (bytes_pair / p)
        # stage A + stage B + the natural-order unpermute
        t_comm = 3.0 * (wire / hw.link_bw + COLLECTIVE_LAT)
    else:                                          # pencil
        for p in plan.mesh_shape:
            if p <= 1:
                continue
            wire = (p - 1) / p * (bytes_pair / devices)
            t_comm += wire / hw.link_bw + COLLECTIVE_LAT
    return t_comp + t_comm


# ---------------------------------------------------------------------------
# candidate enumeration + comm resolution
# ---------------------------------------------------------------------------


def _mesh_axis_sizes(mesh, axes) -> "dict[str, int]":
    """Accepts a live ``jax.sharding.Mesh`` OR an abstract ``{name: size}``
    mapping (estimate-only planning without devices, e.g. in benchmarks)."""
    if mesh is None:
        return {}
    if isinstance(mesh, dict):
        sizes = dict(mesh)
    else:
        sizes = {a: mesh.shape[a] for a in mesh.axis_names}
    if axes is not None:
        sizes = {a: sizes[a] for a in axes}
    return sizes


def _candidates(shape, kind, sizes,
                output_layout: str = "natural"
                ) -> Sequence[Tuple[str, Tuple[str, ...]]]:
    """(decomp, mesh_axes) candidates the shape/mesh combination supports."""
    d = len(shape)
    live = [a for a, p in sizes.items() if p > 1]
    cands = [("local", ())]
    if d >= 2:
        cands += [("slab", (a,)) for a in live]
    if d >= 3:
        # multi-axis pencil: every ordered tuple of 2..ndim-1 mesh axes
        # (the k leading transform axes are sharded, one exchange per
        # adjacent pair of the chain)
        for k in range(2, min(d - 1, len(live)) + 1):
            cands += [("pencil", axes)
                      for axes in itertools.permutations(live, k)]
    if d == 1 and kind == "c2c" and output_layout == "natural":
        # distributed 1D through the fft_conv factor split (three
        # exchanges; output is natural-order, so no transposed layout)
        cands += [("factor1d", (a,)) for a in live
                  if factor_split(shape[0], sizes[a]) is not None]
    return cands


def _plan_factors(decomp: str, shape, mesh_axes, sizes) -> Tuple[int, ...]:
    """The (n1, n2) split a ``factor1d`` candidate executes; () otherwise."""
    if decomp != "factor1d":
        return ()
    split = factor_split(shape[0], sizes[mesh_axes[0]])
    if split is None:
        raise ValueError(
            f"no factor split of n={shape[0]} over p={sizes[mesh_axes[0]]} "
            "(need n divisible by p**2 with factorizable factors)")
    return split


def _resolve_comm(decomp, mesh_axes, shape, kind, comm, mesh, sizes,
                  planner, factors=()) -> Tuple:
    """Turn the user's ``comm`` argument into one concrete spec per mesh
    axis.  ``"auto"`` entries go through the roofline planners,
    ``"measure"`` entries through the on-mesh autotuners (live mesh only);
    explicit names / CommBackend instances / per-axis collections pass
    through as in the historical entry points."""
    if decomp == "local":
        return ()
    live = mesh is not None and not isinstance(mesh, dict)
    specs = list(_normalize_axis_specs(comm, mesh_axes))
    if decomp in ("slab", "factor1d"):
        (a,) = mesh_axes
        if specs[0] == "auto":
            if decomp == "factor1d":
                specs[0] = plan_comm_factor1d(shape[0], factors[0],
                                              factors[1], sizes[a],
                                              hw=planner.hw)
            else:
                specs[0] = plan_comm_slab_nd(shape, sizes[a], hw=planner.hw,
                                             kind=kind)
        elif specs[0] == "measure":
            if not live:
                raise ValueError('comm="measure" needs a live mesh')
            if decomp == "factor1d":
                specs[0] = measure_comm_factor1d(shape[0], tuple(factors),
                                                 mesh, a,
                                                 wisdom=planner.wisdom)
            else:
                specs[0] = measure_comm_slab_nd(shape, mesh, a, kind=kind,
                                                wisdom=planner.wisdom)
        return tuple(specs)
    # pencil: plan/measure per mesh axis, only the axes that ask
    if "auto" in specs:
        ps = tuple(sizes[a] for a in mesh_axes)
        planned = plan_comm_pencil_nd(shape, ps, hw=planner.hw, kind=kind)
        specs = [planned[i] if s == "auto" else s for i, s in enumerate(specs)]
    if "measure" in specs:
        if not live:
            raise ValueError('comm="measure" needs a live mesh')
        measured = measure_comm_pencil_nd(
            tuple(shape), mesh, mesh_axes, kind=kind, wisdom=planner.wisdom,
            which=tuple(s == "measure" for s in specs))
        specs = [measured[i] if s == "measure" else s
                 for i, s in enumerate(specs)]
    return tuple(specs)


def _comm_tag(comm) -> Optional[str]:
    """Stable wisdom-key tag for a comm argument, or None if uncacheable
    (CommBackend instances are process-local objects)."""
    if isinstance(comm, str):
        return comm
    if isinstance(comm, (list, tuple)) and all(isinstance(s, str)
                                               for s in comm):
        return ",".join(comm)
    if isinstance(comm, dict) and all(isinstance(s, str)
                                      for s in comm.values()):
        return ",".join(f"{k}={v}" for k, v in sorted(comm.items()))
    return None


# ---------------------------------------------------------------------------
# plan_nd (the guru interface)
# ---------------------------------------------------------------------------


def plan_nd(shape: Sequence[int], kind: str = "c2c", mesh=None,
            axes: Optional[Sequence[str]] = None, mode: str = "estimate",
            comm="auto", planner: Optional[Planner] = None,
            decomp: Optional[str] = None,
            output_layout: str = "natural") -> NdPlan:
    """Plan one N-D transform: pick the decomposition, the mesh-axis
    assignment, and the exchange backends; return the :class:`NdPlan`.

    ``shape``: transform shape (trailing axes; leading input axes are
    batch).  ``kind``: ``"c2c"`` or ``"r2c"`` (the plan serves the inverse
    too).  ``mesh``: a live ``jax.sharding.Mesh``, an abstract
    ``{axis_name: size}`` mapping (estimate-only), or None for single
    device.  ``axes`` restricts which mesh axes the planner may use.

    ``mode="estimate"`` scores candidates with the roofline model;
    ``mode="measured"`` also times the finalists on the live mesh (FFTW
    MEASURE applied to the decomposition choice).  ``comm`` is any spec the
    historical entry points accepted — a backend name/instance,
    ``"auto"``, ``"measure"``, or a per-mesh-axis collection for pencil.

    ``output_layout="transposed"`` plans for a spectrum sharded over the
    last transform axis (slab saves its restore exchange; the same plan
    passed to ``ifftn`` inverts the transposed spectrum without a
    re-shuffle).  Values keep their natural numpy positions either way.

    ``decomp`` forces a decomposition (the deprecated shims use this); the
    verdict of a free choice is cached under a ``dfft/v2/*`` wisdom key
    (pre-bump ``dfft/*`` entries are migrated on first lookup).
    """
    shape = tuple(int(n) for n in shape)
    assert kind in ("c2c", "r2c"), kind
    assert mode in ("estimate", "measured"), mode
    assert output_layout in OUTPUT_LAYOUTS, output_layout
    planner = planner or Planner(backends=("jnp",))
    sizes = _mesh_axis_sizes(mesh, axes)
    live = mesh is not None and not isinstance(mesh, dict)

    def build(dec, mesh_axes, est=0.0, measured=-1.0, comm_arg=None):
        factors = _plan_factors(dec, shape, mesh_axes, sizes)
        return NdPlan(
            shape, kind, dec, tuple(mesh_axes),
            tuple(sizes[a] for a in mesh_axes),
            _resolve_comm(dec, tuple(mesh_axes), shape, kind,
                          comm if comm_arg is None else comm_arg, mesh,
                          sizes, planner, factors=factors),
            mode, est, measured, output_layout, factors)

    if decomp is not None:              # forced (shims, benchmarks)
        assert decomp in DECOMPS, decomp
        if decomp == "factor1d" and output_layout == "transposed":
            raise ValueError("factor1d output is natural-order only")
        if decomp == "slab" and len(shape) < 2:
            raise ValueError("slab decomposition needs ndim >= 2")
        if decomp == "factor1d" and (len(shape) != 1 or kind != "c2c"):
            raise ValueError("factor1d is the 1D c2c decomposition")
        if decomp == "local":
            mesh_axes = ()
        elif axes is not None:
            mesh_axes = tuple(axes)
        else:
            width = 1 if decomp in ("slab", "factor1d") else \
                min(len(sizes), len(shape) - 1)
            mesh_axes = tuple(list(sizes)[:width])
        if decomp == "pencil" and not 2 <= len(mesh_axes) <= len(shape) - 1:
            raise ValueError(
                f"pencil needs 2..ndim-1 mesh axes, got {mesh_axes} for "
                f"ndim={len(shape)}")
        nd = build(decomp, mesh_axes)
        return dataclasses.replace(
            nd, est_cost=_estimate_nd(nd, planner.hw, on_mesh=bool(sizes)))

    key = None
    tag = _comm_tag(comm)
    if tag is not None:
        mesh_tag = ".".join(f"{a}{p}" for a, p in sizes.items()) or "none"
        key = (f"dfft/v2/{'x'.join(str(n) for n in shape)}/{kind}/"
               f"{mesh_tag}/{mode}/{tag}/{output_layout}")
        hit = planner.wisdom.get(key)
        if hit is not None and not _valid_verdict(hit):
            # corrupt v2 record: re-plan (the fresh verdict overwrites it)
            hit = None
        if hit is None and output_layout == "natural":
            hit = _migrate_v1_verdict(planner, shape, kind, mesh_tag, mode,
                                      tag, key)
        if hit is not None:
            return NdPlan(shape, kind, hit["decomp"],
                          tuple(hit["mesh_axes"]), tuple(hit["mesh_shape"]),
                          tuple(hit["comm"]), mode, hit.get("est", 0.0),
                          hit.get("measured", -1.0),
                          hit.get("output_layout", "natural"),
                          tuple(hit.get("factors", ())))

    scored = []
    for dec, mesh_axes in _candidates(shape, kind, sizes, output_layout):
        nd = NdPlan(shape, kind, dec, mesh_axes,
                    tuple(sizes[a] for a in mesh_axes), (), mode,
                    output_layout=output_layout,
                    factors=_plan_factors(dec, shape, mesh_axes, sizes))
        scored.append((_estimate_nd(nd, planner.hw, on_mesh=bool(sizes)),
                       nd))
    scored.sort(key=lambda t: t[0])

    if mode == "measured" and live and len(scored) > 1:
        # measured mode prices every finalist with its best exchange:
        # "auto" comm upgrades to the on-mesh measure_comm_* autotuners
        m_comm = "measure" if comm == "auto" else comm
        best = _measure_finalists(scored, shape, kind, mesh, planner,
                                  lambda dec, axes_, est: build(
                                      dec, axes_, est=est, comm_arg=m_comm))
    else:
        est, nd = scored[0]
        best = build(nd.decomp, nd.mesh_axes, est=est)

    if key is not None and _comm_tag(best.comm) is not None:
        planner.wisdom.put(key, {
            "decomp": best.decomp, "mesh_axes": list(best.mesh_axes),
            "mesh_shape": list(best.mesh_shape), "comm": list(best.comm),
            "est": best.est_cost, "measured": best.measured_cost,
            "output_layout": best.output_layout,
            "factors": list(best.factors)})
    return best


def _valid_verdict(rec) -> bool:
    """A ``dfft/*`` wisdom record trustworthy enough to reconstruct a plan
    from (truncated/hand-edited records fall through to re-planning — the
    store is a cache, never ground truth)."""
    return (isinstance(rec, dict)
            and rec.get("decomp") in DECOMPS
            and all(isinstance(rec.get(f), list)
                    for f in ("mesh_axes", "mesh_shape", "comm"))
            and (rec["decomp"] != "factor1d"
                 or len(rec.get("factors") or ()) == 2))


def _migrate_v1_verdict(planner, shape, kind, mesh_tag, mode, tag,
                        v2_key) -> Optional[dict]:
    """Adopt a pre-bump ``dfft/*`` (v1) wisdom verdict for a natural-layout
    lookup: the v1 schema had no ``output_layout``/``factors`` fields (and
    no ``factor1d`` decomposition), so a v1 record is exactly a v2
    natural-layout record with the new fields defaulted.  The migrated
    record is re-written under the v2 key so the v1 entry is consulted at
    most once per key."""
    v1_key = (f"dfft/{'x'.join(str(n) for n in shape)}/{kind}/"
              f"{mesh_tag}/{mode}/{tag}")
    old = planner.wisdom.get(v1_key)
    # the v1 schema predates factor1d, so a factor1d decomp marks the
    # record as garbage rather than a migratable verdict
    if (not _valid_verdict(old)
            or old["decomp"] not in ("local", "slab", "pencil")):
        return None        # corrupt/truncated v1 record: re-plan instead
    rec = dict(old)
    rec.setdefault("output_layout", "natural")
    rec.setdefault("factors", [])
    planner.wisdom.put(v2_key, rec)
    return rec


def _measure_finalists(scored, shape, kind, mesh, planner, build) -> NdPlan:
    """FFTW MEASURE over decompositions: execute each finalist's forward
    transform once-compiled on the live mesh and keep the fastest.  Each
    finalist's exchanges resolve through the comm autotuners first (their
    verdicts land in ``comm/*`` wisdom as usual), so the measurement prices
    the decomposition with its best available exchange."""
    rng = np.random.default_rng(0)
    if kind == "r2c":
        probe = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    else:
        probe = tuple(jnp.asarray(
            rng.standard_normal(shape).astype(np.float32)) for _ in range(2))
    best, best_t = None, float("inf")
    # finalists = the roofline's top 3, mirroring how Planner._measure caps
    # its candidate sweep — timing the model's last-ranked candidates buys
    # nothing and each one costs a compile + a comm chunk-sweep
    for est, cand in scored[:3]:
        nd = build(cand.decomp, cand.mesh_axes, est)

        def run(*args):
            a = args[0] if nd.kind == "r2c" else args
            return execute_nd(nd, a, mesh=mesh, planner=planner)

        args = (probe,) if kind == "r2c" else probe
        dt = _time_callable(jax.jit(run), args, reps=3)
        if dt != float("inf"):
            PLAN_ND_STATS["timed"] += 1
        if dt < best_t:
            best, best_t = nd, dt
    assert best is not None
    return dataclasses.replace(best, measured_cost=best_t)


# ---------------------------------------------------------------------------
# execution (dispatch to the shared executors in repro.core.dfft)
# ---------------------------------------------------------------------------


def execute_nd(plan: NdPlan, x, mesh=None, planner: Optional[Planner] = None,
               chunks: int = 4, **layout_opts):
    """Run ``plan`` forward.  ``x``: real array for r2c, (re, im) pair for
    c2c (leading batch dims welcome).  Returns the PADDED spectrum pair —
    crop with ``plan.crop`` / ``plan.crop_pair`` for the exact transform.
    The output layout follows ``plan.output_layout`` (transposed slab
    plans skip the restore exchange); ``layout_opts`` are the LEGACY
    2D-slab-only flags ``keep_transposed``/``permuted_cols`` the
    deprecated shims still pass.
    """
    planner = planner or Planner(backends=("jnp",))
    if plan.decomp == "local":
        return _execute_local(plan, x, planner)
    assert mesh is not None, "distributed plans need the live mesh"
    if plan.decomp == "slab":
        return dfft.execute_slab(plan, x, mesh, planner, chunks=chunks,
                                 **layout_opts)
    if plan.decomp == "factor1d":
        return dfft.execute_factor1d(plan, x, mesh, planner, chunks=chunks)
    return dfft.execute_pencil(plan, x, mesh, planner, chunks=chunks)


def execute_nd_inverse(plan: NdPlan, c: Complex, mesh=None,
                       planner: Optional[Planner] = None, chunks: int = 4,
                       **layout_opts):
    """Run ``plan`` backward from the PADDED spectrum pair.  Returns a pair
    for c2c, a real array for r2c; sharded axes keep their divisibility
    padding (crop trailing axes to ``plan.shape``)."""
    planner = planner or Planner(backends=("jnp",))
    if plan.decomp == "local":
        return _execute_local_inverse(plan, c, planner)
    assert mesh is not None, "distributed plans need the live mesh"
    if plan.decomp == "slab":
        return dfft.execute_slab_inverse(plan, c, mesh, planner,
                                         chunks=chunks, **layout_opts)
    if plan.decomp == "factor1d":
        return dfft.execute_factor1d_inverse(plan, c, mesh, planner,
                                             chunks=chunks)
    return dfft.execute_pencil_inverse(plan, c, mesh, planner, chunks=chunks)


def _execute_local(plan: NdPlan, x, planner: Planner):
    """Single-device N-D transform: planned 1D stages, axis by axis."""
    d = len(plan.shape)
    if plan.kind == "r2c":
        y = dfft.rows_rfft(planner, x, plan.shape[-1])
    else:
        y = execute(planner.plan(plan.shape[-1], kind="c2c"), x)
    for k in range(d - 2, -1, -1):
        y = dfft._fft_axis(planner.plan(plan.shape[k], kind="c2c"), y,
                           y[0].ndim - d + k)
    return y


def _execute_local_inverse(plan: NdPlan, c: Complex, planner: Planner):
    d = len(plan.shape)
    y = c
    for k in range(d - 1):
        y = dfft._fft_axis(planner.plan(plan.shape[k], kind="c2c"), y,
                           y[0].ndim - d + k, inverse=True)
    if plan.kind == "r2c":
        return dfft.rows_irfft(planner, y, plan.shape[-1])
    return execute_inverse(planner.plan(plan.shape[-1], kind="c2c"), y)


# ---------------------------------------------------------------------------
# the fftn family (numpy-shaped conveniences over plan_nd)
# ---------------------------------------------------------------------------


def _as_pair(x) -> Complex:
    if isinstance(x, (tuple, list)):
        return tuple(x)
    if jnp.iscomplexobj(x):
        return algo.to_pair(x)
    x = jnp.asarray(x)
    return x.astype(jnp.float32), jnp.zeros_like(x, jnp.float32)


def _transform_ndim(x, ndim, plan) -> int:
    if plan is not None:
        return len(plan.shape)
    arr = x[0] if isinstance(x, (tuple, list)) else x
    return arr.ndim if ndim is None else ndim


def _pad_spectrum(c: Complex, plan: NdPlan) -> Complex:
    """Zero-pad an exact spectrum pair back to the executor's padded layout
    (the padded bands are zero by construction, so this is lossless)."""
    d = len(plan.shape)
    for ax_off, (true, padded) in enumerate(zip(plan.spectrum_shape,
                                                plan.padded_spectrum_shape)):
        if true != padded:
            c = dfft._pad_axis(c, c[0].ndim - d + ax_off, padded)
    return c


def _crop_spatial(y, plan: NdPlan, pair: bool):
    """Crop the inverse executors' output back to ``plan.shape``."""
    d = len(plan.shape)
    for ax_off, (true, padded) in enumerate(zip(plan.shape,
                                                plan.padded_input_shape)):
        if true != padded:
            if pair:
                y = dfft._crop_axis(y, y[0].ndim - d + ax_off, true)
            else:
                y = jax.lax.slice_in_dim(y, 0, true,
                                         axis=y.ndim - d + ax_off)
    return y


def fftn(x, mesh=None, axes=None, planner: Optional[Planner] = None,
         comm="auto", mode: str = "estimate", ndim: Optional[int] = None,
         plan: Optional[NdPlan] = None, chunks: int = 4,
         output_layout: str = "natural") -> Complex:
    """N-D c2c FFT matching ``numpy.fft.fftn`` over the trailing ``ndim``
    axes (default: all).  ``x``: complex array or (re, im) pair; leading
    axes beyond ``ndim`` are batch.  Decomposition, mesh-axis assignment,
    exchange backends and output layout come from :func:`plan_nd` (or pass
    ``plan=``).  Returns an (re, im) pair with the exact numpy shape (with
    ``output_layout="transposed"`` the values are identical but the
    spectrum stays sharded over the last transform axis)."""
    if isinstance(mesh, int):   # legacy repro.core.fftn(pair, ndim) call
        import warnings
        warnings.warn(
            "fftn(x, ndim) is the old repro.core.algo.fftn signature; "
            "repro.core.fftn is now the planned front-end — pass ndim=... "
            "(or call repro.core.algo.fftn directly)",
            DeprecationWarning, stacklevel=2)
        mesh, ndim = None, mesh
    c = _as_pair(x)
    d = _transform_ndim(c, ndim, plan)
    plan = plan or plan_nd(c[0].shape[c[0].ndim - d:], "c2c", mesh=mesh,
                           axes=axes, mode=mode, comm=comm, planner=planner,
                           output_layout=output_layout)
    out = execute_nd(plan, c, mesh=mesh, planner=planner, chunks=chunks)
    return plan.crop_pair(out)


def ifftn(x, mesh=None, axes=None, planner: Optional[Planner] = None,
          comm="auto", mode: str = "estimate", ndim: Optional[int] = None,
          plan: Optional[NdPlan] = None, chunks: int = 4,
          output_layout: str = "natural") -> Complex:
    """Inverse of :func:`fftn` (matches ``numpy.fft.ifftn``).  Accepts the
    exact spectrum (array or pair); re-pads internally for the collective
    layout.  With a transposed plan (``plan.output_layout="transposed"``
    or ``output_layout=`` here) the transposed spectrum inverts without a
    re-shuffle: the slab inverse skips its first exchange."""
    c = _as_pair(x)
    d = _transform_ndim(c, ndim, plan)
    plan = plan or plan_nd(c[0].shape[c[0].ndim - d:], "c2c", mesh=mesh,
                           axes=axes, mode=mode, comm=comm, planner=planner,
                           output_layout=output_layout)
    c = _pad_spectrum(c, plan)
    y = execute_nd_inverse(plan, c, mesh=mesh, planner=planner,
                           chunks=chunks)
    return _crop_spatial(y, plan, pair=True)


def rfftn(x: jax.Array, mesh=None, axes=None,
          planner: Optional[Planner] = None, comm="auto",
          mode: str = "estimate", ndim: Optional[int] = None,
          plan: Optional[NdPlan] = None, chunks: int = 4,
          output_layout: str = "natural") -> Complex:
    """N-D r2c FFT matching ``numpy.fft.rfftn`` over the trailing ``ndim``
    axes of a real array (odd last-axis lengths included).  Returns the
    exact half-spectrum pair."""
    x = jnp.asarray(x)
    d = _transform_ndim(x, ndim, plan)
    plan = plan or plan_nd(x.shape[x.ndim - d:], "r2c", mesh=mesh,
                           axes=axes, mode=mode, comm=comm, planner=planner,
                           output_layout=output_layout)
    out = execute_nd(plan, x.astype(jnp.float32), mesh=mesh, planner=planner,
                     chunks=chunks)
    return plan.crop_pair(out)


def irfftn(x, shape: Optional[Sequence[int]] = None, mesh=None, axes=None,
           planner: Optional[Planner] = None, comm="auto",
           mode: str = "estimate", plan: Optional[NdPlan] = None,
           chunks: int = 4, output_layout: str = "natural") -> jax.Array:
    """Inverse of :func:`rfftn` back to a real array (matches
    ``numpy.fft.irfftn``).  ``shape`` is the spatial transform shape; when
    omitted the last axis is assumed even (``2 * (mh - 1)``), exactly
    numpy's convention."""
    c = _as_pair(x)
    if plan is None:
        if shape is None:       # no batch dims: every input axis transforms
            shape = c[0].shape[:-1] + (2 * (c[0].shape[-1] - 1),)
        shape = tuple(int(n) for n in shape)
        plan = plan_nd(shape, "r2c", mesh=mesh, axes=axes, mode=mode,
                       comm=comm, planner=planner,
                       output_layout=output_layout)
    c = _pad_spectrum(c, plan)
    y = execute_nd_inverse(plan, c, mesh=mesh, planner=planner,
                           chunks=chunks)
    return _crop_spatial(y, plan, pair=False)
