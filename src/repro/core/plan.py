"""FFTW-style planning for the matmul FFT.

The paper shows FFTW's behaviour is dominated by *planning*: estimated plans
are cheap but can leave >5x performance on the table for threaded backends;
measured plans cost >50x more planning time but rescue scaling (Figs. 3-5).

We reproduce that trade-off natively:

* ``estimate``  — analytic roofline cost model over candidate (factorization,
  backend, layout) tuples, using a ``HardwareSpec``; O(us) planning.
* ``measured``  — compile and time every candidate on the local device (like
  FFTW's MEASURE dynamic programming over codelets) and keep the fastest.
* wisdom       — plans are cached by (n, kind, batch-bucket, mode, backend
  restriction) in-process and optionally persisted to a JSON wisdom file,
  exactly like FFTW wisdom.  The store (:class:`repro.core.wisdom.WisdomStore`)
  is shared with the communication autotuner: ``plan/*`` keys live next to
  the ``comm/*`` verdicts of :func:`repro.core.comm.measure_comm`, and the
  ``export_wisdom`` / ``import_wisdom`` / ``forget_wisdom`` methods mirror
  FFTW's API over the whole unified store.

A ``Plan`` is a pure-data recipe; ``execute`` closes over it.  Plans are
reusable across arrays with the same trailing length (batch size is free),
matching FFTW semantics where a plan is tied to the FFT length.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import algo
from .wisdom import WisdomStore, batch_bucket

# ---------------------------------------------------------------------------
# hardware profiles (roofline constants)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    flops: float          # peak FLOP/s (f32 matmul units)
    hbm_bw: float         # bytes/s main-memory bandwidth
    link_bw: float        # bytes/s per interconnect link
    matmul_dim: int       # native matmul tile (MXU = 128)
    vmem_bytes: int       # fast scratch (VMEM / L2)


TPU_V5E = HardwareSpec("tpu_v5e", flops=197e12 / 2, hbm_bw=819e9, link_bw=50e9,
                       matmul_dim=128, vmem_bytes=128 * 2 ** 20)
# f32 matmul on v5e runs at half bf16 rate; FFT twiddles/DFT matrices are f32.
CPU_LOCAL = HardwareSpec("cpu_local", flops=5e9, hbm_bw=20e9, link_bw=1e9,
                         matmul_dim=8, vmem_bytes=32 * 2 ** 20)


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

BACKENDS = ("jnp", "jnp_karatsuba", "pallas", "pallas_karatsuba", "xla_native")


@dataclasses.dataclass(frozen=True)
class Plan:
    """A 1D FFT recipe (FFTW: one plan per transform length)."""
    n: int
    kind: str                       # "c2c" | "r2c" | "c2r"
    factors: Tuple[int, ...]
    backend: str                    # one of BACKENDS
    permuted: bool = False          # skip digit transpose (conv pipelines)
    est_cost: float = 0.0           # seconds, from the cost model
    measured_cost: float = -1.0     # seconds, if mode == "measured"

    @property
    def karatsuba(self) -> bool:
        return self.backend.endswith("karatsuba")

    def flops(self, batch: int) -> float:
        """Real-MAC flop count for one batched apply."""
        if self.backend == "xla_native":
            return 5.0 * batch * self.n * max(np.log2(self.n), 1)
        n_eff = self.n // 2 if self.kind in ("r2c", "c2r") else self.n
        muls = 3 if self.karatsuba else 4
        return 2.0 * muls * batch * n_eff * sum(self.factors)

    def bytes_moved(self, batch: int) -> float:
        """HBM traffic estimate: each four-step stage reads+writes the array."""
        n_eff = self.n // 2 if self.kind in ("r2c", "c2r") else self.n
        passes = max(len(self.factors), 1) + (0 if self.permuted else 1)
        return 2.0 * passes * batch * n_eff * 8.0  # (re, im) f32


def _candidate_factorizations(n: int, max_base: int) -> Sequence[Tuple[int, ...]]:
    """All 1/2/3-way splits with every factor <= max_base (dedup, sorted)."""
    cands = set()
    if n <= max_base:
        cands.add((n,))
    for f1 in range(2, max_base + 1):
        if n % f1:
            continue
        r1 = n // f1
        if r1 <= max_base:
            cands.add(tuple(sorted((f1, r1), reverse=True)))
        for f2 in range(2, max_base + 1):
            if r1 % f2:
                continue
            r2 = r1 // f2
            if r2 <= max_base:
                cands.add(tuple(sorted((f1, f2, r2), reverse=True)))
    return sorted(cands)


class Planner:
    """Creates and caches plans. ``mode``: "estimate" | "measured"."""

    def __init__(self, hardware: HardwareSpec = TPU_V5E,
                 mode: str = "estimate", max_base: int = 128,
                 wisdom_path: Optional[str] = None,
                 backends: Sequence[str] = ("jnp",),
                 wisdom: Optional[WisdomStore] = None):
        assert mode in ("estimate", "measured")
        self.hw = hardware
        self.mode = mode
        self.max_base = max_base
        self.backends = tuple(backends)
        # a shared store may be passed in (e.g. one file for several
        # planners + the comm autotuner); otherwise open/create our own.
        self.wisdom = wisdom if wisdom is not None else WisdomStore(wisdom_path)
        self.wisdom_path = self.wisdom.path
        self.last_plan_seconds: float = 0.0

    # -- FFTW-style wisdom API (unified plan/* + comm/* store) ---------------

    def export_wisdom(self) -> str:
        return self.wisdom.export_wisdom()

    def import_wisdom(self, text: str, replace: bool = False) -> int:
        return self.wisdom.import_wisdom(text, replace=replace)

    def forget_wisdom(self, prefix: str = "") -> int:
        return self.wisdom.forget_wisdom(prefix)

    # -- cost model ---------------------------------------------------------

    def _estimate_seconds(self, plan: Plan, batch: int) -> float:
        hw = self.hw
        t_compute = plan.flops(batch) / hw.flops
        t_mem = plan.bytes_moved(batch) / hw.hbm_bw
        # matmul efficiency penalty: factors far below the MXU tile waste lanes
        if plan.backend != "xla_native" and plan.factors:
            util = min(min(plan.factors) / hw.matmul_dim, 1.0)
            t_compute = t_compute / max(util, 1 / hw.matmul_dim)
        return max(t_compute, t_mem)

    # -- plan construction ---------------------------------------------------

    def _candidates(self, n: int, kind: str, permuted: bool):
        n_eff = n // 2 if kind in ("r2c", "c2r") else n
        for backend in self.backends:
            if backend == "xla_native":
                yield Plan(n, kind, (), backend)
                continue
            for fac in _candidate_factorizations(n_eff, self.max_base):
                if permuted and len(fac) != 2:
                    continue
                yield Plan(n, kind, fac, backend, permuted=permuted)

    def plan(self, n: int, kind: str = "c2c", batch: int = 1,
             permuted: bool = False) -> Plan:
        key = (f"plan/{n}/{kind}/b{batch_bucket(batch)}/{self.mode}/"
               f"{permuted}/{','.join(self.backends)}")
        w = self.wisdom.get(key)
        if w is not None:
            self.last_plan_seconds = 0.0
            return Plan(n, kind, tuple(w["factors"]), w["backend"], permuted,
                        w.get("est", 0.0), w.get("measured", -1.0))
        t0 = time.perf_counter()
        cands = [dataclasses.replace(p, est_cost=self._estimate_seconds(p, batch))
                 for p in self._candidates(n, kind, permuted)]
        if not cands:
            raise ValueError(f"no plan candidates for n={n} ({kind})")
        cands.sort(key=lambda p: p.est_cost)
        if self.mode == "estimate":
            best = cands[0]
        else:
            best = self._measure(cands[: min(len(cands), 12)], n, kind, batch)
        self.last_plan_seconds = time.perf_counter() - t0
        self.wisdom.put(key, {"factors": list(best.factors),
                              "backend": best.backend,
                              "est": best.est_cost,
                              "measured": best.measured_cost})
        return best

    # -- N-D decomposition planning (the guru interface) ----------------------

    def plan_nd(self, shape, kind: str = "c2c", mesh=None, axes=None,
                mode: Optional[str] = None, comm="auto", decomp=None,
                output_layout: str = "natural"):
        """Plan an N-D (possibly distributed) transform with THIS planner's
        hardware profile and wisdom store (delegates to
        :func:`repro.core.api.plan_nd`).  ``mode`` defaults to the
        planner's own mode, so a measured Planner measures decompositions
        too."""
        from .api import plan_nd
        if mode is None:
            mode = "measured" if self.mode == "measured" else "estimate"
        return plan_nd(shape, kind, mesh=mesh, axes=axes, mode=mode,
                       comm=comm, planner=self, decomp=decomp,
                       output_layout=output_layout)

    # -- communication planning (paper §5.3: parcelport choice) ---------------

    def plan_comm(self, n: int, m: int, p: int,
                  overlap_capable: bool = True) -> str:
        """Pick the slab exchange backend for this planner's hardware
        (delegates to :func:`repro.core.comm.plan_comm`)."""
        from .comm import plan_comm
        return plan_comm(n, m, p, hw=self.hw,
                         overlap_capable=overlap_capable)

    def plan_comm_pencil(self, shape, mesh_shape, kind: str = "c2c",
                         overlap_capable: bool = True):
        """Pick per-mesh-axis pencil exchange backends for this planner's
        hardware (delegates to :func:`repro.core.comm.plan_comm_pencil`)."""
        from .comm import plan_comm_pencil
        return plan_comm_pencil(shape, mesh_shape, hw=self.hw,
                                overlap_capable=overlap_capable, kind=kind)

    # -- measured planning (FFTW MEASURE) -------------------------------------

    def _measure(self, cands: Sequence[Plan], n: int, kind: str, batch: int) -> Plan:
        best, best_t = None, float("inf")
        if kind == "c2c":
            probe = (jnp.ones((batch, n), jnp.float32), jnp.zeros((batch, n), jnp.float32))
        else:
            probe = jnp.ones((batch, n), jnp.float32)
        for p in cands:
            try:
                fn = jax.jit(lambda a, _p=p: execute(_p, a))
                out = fn(probe)
                jax.block_until_ready(out)
                reps, t0 = 3, time.perf_counter()
                for _ in range(reps):
                    out = fn(probe)
                jax.block_until_ready(out)
                dt = (time.perf_counter() - t0) / reps
            except Exception:
                continue
            if dt < best_t:
                best, best_t = p, dt
        assert best is not None
        return dataclasses.replace(best, measured_cost=best_t)


# ---------------------------------------------------------------------------
# plan execution
# ---------------------------------------------------------------------------


def execute(plan: Plan, x, **kw):
    """Apply a plan along the last axis. c2c takes/returns an (re, im) pair;
    r2c takes a real array and returns a pair; c2r the reverse."""
    if plan.backend == "xla_native":
        if plan.kind == "c2c":
            z = jnp.fft.fft(algo.to_complex(x))
            return jnp.real(z), jnp.imag(z)
        if plan.kind == "r2c":
            z = jnp.fft.rfft(x.astype(jnp.float32))
            return jnp.real(z), jnp.imag(z)
        return jnp.fft.irfft(algo.to_complex(x)).astype(jnp.float32)

    if plan.backend.startswith("pallas"):
        from repro.kernels.dft_matmul import ops as dft_ops
        if plan.kind == "c2c" and len(plan.factors) == 2:
            return dft_ops.fft_four_step(x, plan.factors, karatsuba=plan.karatsuba,
                                         permuted=plan.permuted, **kw)
        # pallas path only covers the 2-factor c2c hot loop; fall through for
        # the r2c pack/unpack glue which is bandwidth-trivial.

    opts = dict(factors=plan.factors or None, karatsuba=plan.karatsuba)
    if plan.kind == "c2c":
        return algo.fft(x, permuted=plan.permuted, **opts)
    if plan.kind == "r2c":
        return algo.rfft(x, **opts)
    if plan.kind == "c2r":
        return algo.irfft(x, **opts)
    raise ValueError(plan.kind)


def execute_inverse(plan: Plan, x):
    """Inverse transform matching ``plan`` (c2c only)."""
    assert plan.kind == "c2c"
    if plan.backend == "xla_native":
        z = jnp.fft.ifft(algo.to_complex(x))
        return jnp.real(z), jnp.imag(z)
    if plan.permuted:
        return algo.ifft_from_permuted(x, factors=plan.factors,
                                       karatsuba=plan.karatsuba)
    return algo.ifft(x, factors=plan.factors or None, karatsuba=plan.karatsuba)
