"""Distributed multidimensional FFT on a device mesh (the paper's §3.2/§5.3).

Slab decomposition over one mesh axis, pencil decomposition over an
ordered chain of 2..ndim-1 mesh axes, and the factor-split distributed 1D
transform.  All data movement is EXPLICIT collectives inside ``shard_map``
— the paper's
central design decision ("relying on the implicit communication HPX allows
with AGAS does not make sense; instead we use the HPX equivalents of the MPI
collective operations").

This module holds the *executors*: given an :class:`repro.core.api.NdPlan`
(the pure-data recipe produced by :func:`repro.core.api.plan_nd`), the
``execute_slab`` / ``execute_pencil`` pairs run the decomposed transform on
a live mesh.  The planning — which decomposition, which mesh-axis
assignment, which exchange backend — lives in :mod:`repro.core.api`; the
exchange strategies themselves live in :mod:`repro.core.comm`.

One shared pad-and-crop layer serves every path:

* r2c half spectra are zero-padded to the collective-divisible width
  (``padded_half``), the convention the 2D slab path always had;
* **mixed-radix mesh shapes** — transform axes not divisible by their
  communicator — are handled by zero-padding the axis up to the next
  multiple, cropping to the true length just before the axis is transformed,
  and re-padding after, so the padded band stays exactly zero through every
  exchange and is cropped once at the end (``NdPlan.crop``);
* **leading batch dims** ride through every executor via the batched
  shard_map spec helper (:func:`repro.core.compat.batched_spec`) shared
  with :func:`repro.core.fftconv.fft_conv_seq_sharded`.

Algorithm (slab, 2D r2c, row-major N x M, P devices; paper's five steps):

  1. local r2c FFTs along contiguous rows          (N/P, Mh)
  2. COMMUNICATE: all_to_all column slabs          -> (N, Mh/P)  [rearrange
     = split into N_locs parts + concat, fused into the tiled collective]
  3. transpose AFTER communication (paper's choice) -> (Mh/P, N)
  4. local c2c FFTs along (now contiguous) columns
  5. COMMUNICATE back + rearrange to original layout (N/P, Mh)

Pencil decomposition (P3DFFT-style, k mesh axes) has full parity with
slab, and the ``factor1d`` executor distributes a single long axis via the
``fft_conv`` factor split (three 1/P exchanges instead of one full
gather).

The historical shape-specific entry points — ``fft2_slab``/``ifft2_slab``
and the four ``*_pencil`` functions — remain as thin DEPRECATED shims that
build an ``NdPlan`` internally and call the shared executors; new code
should go through :func:`repro.core.api.plan_nd` and the ``fftn`` family.
"""

from __future__ import annotations

import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import algo
from .comm import (COMM_BACKENDS, CommBackend, CommSpec, get_backend,
                   measure_comm_pencil, measure_comm_slab, pad_to,
                   padded_half, plan_comm, plan_comm_pencil,
                   resolve_axis_backends)
from .compat import batched_spec, shard_map
from .plan import Plan, Planner, execute, execute_inverse

Complex = algo.Complex

__all__ = [
    "COMM_BACKENDS", "padded_half", "pad_to", "plan_comm", "plan_comm_pencil",
    "measure_comm_slab", "measure_comm_pencil",
    "rows_rfft", "rows_irfft", "hermitian_extend_last",
    "execute_slab", "execute_slab_inverse",
    "execute_pencil", "execute_pencil_inverse",
    "execute_factor1d", "execute_factor1d_inverse",
    "fft2_slab", "ifft2_slab",
    "fft3_pencil", "ifft3_pencil", "rfft3_pencil", "irfft3_pencil",
    "distribute", "collect",
]


# ---------------------------------------------------------------------------
# shared pad-and-crop layer (every decomposition path goes through these)
# ---------------------------------------------------------------------------


def _pad_axis(c: Complex, axis: int, target: int) -> Complex:
    """Zero-pad one axis of an (re, im) pair up to ``target`` entries."""
    pad = target - c[0].shape[axis]
    if pad <= 0:
        return c
    widths = [(0, 0)] * c[0].ndim
    widths[axis] = (0, pad)
    return jnp.pad(c[0], widths), jnp.pad(c[1], widths)


def _crop_axis(c: Complex, axis: int, n: int) -> Complex:
    """Crop one axis of a pair back to its true length ``n``."""
    if c[0].shape[axis] == n:
        return c
    return (jax.lax.slice_in_dim(c[0], 0, n, axis=axis),
            jax.lax.slice_in_dim(c[1], 0, n, axis=axis))


def _fft_axis(plan: Plan, c: Complex, axis: int, inverse: bool = False
              ) -> Complex:
    """c2c transform along one (fully local) axis of a pair."""
    if axis == c[0].ndim - 1 or axis == -1:
        return execute_inverse(plan, c) if inverse else execute(plan, c)
    ct = (jnp.moveaxis(c[0], axis, -1), jnp.moveaxis(c[1], axis, -1))
    zt = execute_inverse(plan, ct) if inverse else execute(plan, ct)
    return jnp.moveaxis(zt[0], -1, axis), jnp.moveaxis(zt[1], -1, axis)


def hermitian_extend_last(c: Complex, n: int) -> Complex:
    """Rebuild the full length-``n`` spectrum from the half spectrum of a
    real signal along the last axis: ``F[k] = conj(F[n-k])`` for k > n//2.
    Valid whenever every other axis is already in its real/spatial form."""
    mh = n // 2 + 1
    idx = np.arange(n - mh, 0, -1)          # tail k = mh..n-1  <-  n-k
    return (jnp.concatenate([c[0], c[0][..., idx]], -1),
            jnp.concatenate([c[1], -c[1][..., idx]], -1))


def rows_rfft(planner: Planner, x: jax.Array, n: int) -> Complex:
    """r2c FFT along the last axis for ANY length: even lengths use the
    packed real codelet path, odd lengths fall back to a c2c transform of
    the real signal cropped to the half spectrum."""
    if n % 2 == 0:
        return execute(planner.plan(n, kind="r2c"), x)
    re, im = execute(planner.plan(n, kind="c2c"), (x, jnp.zeros_like(x)))
    return re[..., : n // 2 + 1], im[..., : n // 2 + 1]


def rows_irfft(planner: Planner, c: Complex, n: int) -> jax.Array:
    """c2r inverse of :func:`rows_rfft` (input ``(..., n//2+1)``)."""
    if n % 2 == 0:
        return execute(planner.plan(n, kind="c2r"), c)
    full = hermitian_extend_last(c, n)
    return execute_inverse(planner.plan(n, kind="c2c"), full)[0]


def _warm_rows_plan(planner: Planner, n: int, inverse: bool = False) -> None:
    """Pre-plan the 1D stage :func:`rows_rfft` / :func:`rows_irfft` will
    request, OUTSIDE any traced function — their trace-time lookups then hit
    the planner's wisdom cache without triggering a wisdom write."""
    if n % 2 == 0:
        planner.plan(n, kind="c2r" if inverse else "r2c")
    else:
        planner.plan(n, kind="c2c")


def _local_rows_rfft(x: jax.Array, plan: Plan, mh_pad: int) -> Complex:
    """r2c FFT along the last axis + zero-pad to the collective-divisible
    width (works for any number of leading batch axes)."""
    re, im = execute(plan, x)
    return _pad_axis((re, im), -1, mh_pad)


def _slab_backend(nd, chunks: int) -> CommBackend:
    return get_backend(nd.comm[0] if nd.comm else "collective", chunks=chunks)


def _pencil_backends(nd, chunks: int) -> Tuple[CommBackend, ...]:
    return resolve_axis_backends(nd.comm, nd.mesh_axes, chunks=chunks)


def _pencil_spectrum_spec(axs, k: int, d: int) -> P:
    """The pencil SPECTRUM sharding (forward output == inverse input):
    transform axis j+1 over mesh axis j for j < k-1, the last axis over
    mesh axis k-1, everything else replicated.  One definition so the two
    executors can never desynchronize."""
    spec = [None] * d
    for j in range(k - 1):
        spec[j + 1] = axs[j]
    spec[d - 1] = axs[k - 1]
    return P(*spec)


# ---------------------------------------------------------------------------
# slab executor (1 mesh axis, ndim >= 2, leading batch dims, mixed radix)
# ---------------------------------------------------------------------------
#
# Layout (forward, transform shape (n0, ..., nlast), P devices over `ax`):
#
#   input   (b..., n0p/P, ..., nlast)   last-axis FFT (r2c or c2c) local,
#                                       then every middle axis, then pad the
#                                       spectrum's last axis to lp
#   xchg    split last, concat first -> (b..., n0p, ..., lp/P)
#   ax0 FFT crop n0p -> n0, transform, re-pad to n0p
#   xchg    split first, concat last -> (b..., n0p/P, ..., lp)
#
# n0p = pad_to(n0, P); lp = padded_half(nlast, P) for r2c, pad_to(nlast, P)
# for c2c.  The padded bands are exactly zero throughout (zero columns stay
# zero under FFTs along other axes), so `NdPlan.crop` recovers the exact
# spectrum.


def execute_slab(nd, x, mesh: jax.sharding.Mesh, planner: Planner, *,
                 chunks: int = 4, keep_transposed: bool = False,
                 permuted_cols: bool = False):
    """Forward slab transform of an :class:`~repro.core.api.NdPlan`.

    ``x``: real array for ``kind="r2c"``, (re, im) pair for ``"c2c"``, with
    any number of leading batch dims.  Returns the PADDED spectrum pair
    (global trailing shape ``nd.padded_spectrum_shape``), sharded over the
    first transform axis — crop with ``nd.crop`` for the exact transform.

    A plan with ``output_layout="transposed"`` skips the second exchange
    entirely: the values stay at their natural (numpy) index positions but
    the output is sharded over the LAST axis instead of the first (any
    ndim, mixed radix included) — ``execute_slab_inverse`` consumes that
    layout with a single exchange, so a transposed round trip saves two.

    ``keep_transposed`` / ``permuted_cols`` are the historical 2D-only
    layout flags of ``fft2_slab`` (folded transposed layout / skip the
    column digit transpose); new code plans the layout instead.
    """
    d = len(nd.shape)
    assert nd.decomp == "slab" and len(nd.mesh_axes) == 1
    transposed_out = getattr(nd, "output_layout", "natural") == "transposed"
    if keep_transposed or permuted_cols:
        assert d == 2, "transposed/permuted layouts are 2D-only"
        assert not transposed_out, \
            "legacy keep_transposed flag on an already-transposed plan"
    ax, p = nd.mesh_axes[0], nd.mesh_shape[0]
    pair_in = nd.kind == "c2c"
    xr = x[0] if pair_in else x
    bnd = xr.ndim - d
    i0, il = bnd, bnd + d - 1
    n0, nlast = nd.shape[0], nd.shape[-1]
    n0p = pad_to(n0, p)
    lp = nd.padded_spectrum_shape[-1]
    backend = _slab_backend(nd, chunks)

    if keep_transposed and n0p != n0:
        raise ValueError("keep_transposed requires shape[0] divisible by "
                         "the mesh axis (mixed radix keeps both exchanges)")
    row_plan = planner.plan(nlast, kind="c2c") if pair_in else None
    if not pair_in:
        _warm_rows_plan(planner, nlast)
    mid_plans = [planner.plan(nd.shape[k], kind="c2c")
                 for k in range(1, d - 1)]
    col_plan = planner.plan(n0, kind="c2c", permuted=permuted_cols)

    if n0p != n0:                       # mixed radix: zero-pad sharded axis
        widths = [(0, 0)] * xr.ndim
        widths[i0] = (0, n0p - n0)
        x = ((jnp.pad(x[0], widths), jnp.pad(x[1], widths)) if pair_in
             else jnp.pad(x, widths))

    def local(*args):
        if pair_in:
            y = execute(row_plan, args)                     # c2c last axis
            y = _pad_axis(y, il, lp)
        else:
            y = rows_rfft(planner, args[0], nlast)          # r2c last axis
            y = _pad_axis(y, il, lp)
        for k, mp in enumerate(mid_plans):                  # middle axes
            y = _fft_axis(mp, y, i0 + 1 + k)
        y = backend.exchange(y, ax, split=il, concat=i0, p=p)
        y = _crop_axis(y, i0, n0)                           # mixed radix
        y = _fft_axis(col_plan, y, i0)                      # first axis
        if keep_transposed:     # 2D: hand back the transposed local layout
            return jnp.swapaxes(y[0], i0, il), jnp.swapaxes(y[1], i0, il)
        y = _pad_axis(y, i0, n0p)
        if transposed_out:      # planned layout: skip the second exchange
            return y
        return backend.exchange(y, ax, split=i0, concat=il, p=p)

    spec_in = batched_spec(P(ax, *(None,) * (d - 1)), bnd)
    if keep_transposed:
        spec_out = batched_spec(P(None, ax), bnd)
    elif transposed_out:
        spec_out = batched_spec(P(*(None,) * (d - 1), ax), bnd)
    else:
        spec_out = batched_spec(P(ax, *(None,) * (d - 1)), bnd)
    in_specs = (spec_in, spec_in) if pair_in else (spec_in,)
    args = x if pair_in else (x,)
    return shard_map(local, mesh=mesh, in_specs=in_specs,
                     out_specs=(spec_out, spec_out))(*args)


def execute_slab_inverse(nd, c: Complex, mesh: jax.sharding.Mesh,
                         planner: Planner, *, chunks: int = 4,
                         from_transposed: bool = False,
                         permuted_cols: bool = False):
    """Inverse slab transform: consumes the PADDED spectrum pair produced by
    :func:`execute_slab` (zero padded bands) and returns the spatial array —
    real for ``kind="r2c"``, a pair for ``"c2c"`` — with the first transform
    axis still padded to ``pad_to(n0, p)`` (crop with ``nd.shape[0]``).

    A plan with ``output_layout="transposed"`` consumes the last-axis-
    sharded layout :func:`execute_slab` produced for it and needs only ONE
    exchange; the legacy 2D ``from_transposed`` flag consumes the
    historical folded layout instead."""
    d = len(nd.shape)
    assert nd.decomp == "slab" and len(nd.mesh_axes) == 1
    transposed_in = getattr(nd, "output_layout", "natural") == "transposed"
    if from_transposed or permuted_cols:
        assert d == 2, "transposed/permuted layouts are 2D-only"
        assert not transposed_in, \
            "legacy from_transposed flag on an already-transposed plan"
    ax, p = nd.mesh_axes[0], nd.mesh_shape[0]
    bnd = c[0].ndim - d
    i0, il = bnd, bnd + d - 1
    n0, nlast = nd.shape[0], nd.shape[-1]
    n0p = pad_to(n0, p)
    lp = nd.padded_spectrum_shape[-1]
    ltrue = nd.spectrum_shape[-1]       # mh for r2c, nlast for c2c
    backend = _slab_backend(nd, chunks)
    col_plan = planner.plan(n0, kind="c2c", permuted=permuted_cols)
    mid_plans = [planner.plan(nd.shape[k], kind="c2c")
                 for k in range(1, d - 1)]
    row_plan = planner.plan(nlast, kind="c2c") if nd.kind == "c2c" else None
    if nd.kind == "r2c":
        _warm_rows_plan(planner, nlast, inverse=True)

    if from_transposed and n0p != n0:
        raise ValueError("from_transposed requires shape[0] divisible by "
                         "the mesh axis")

    def local(cr: jax.Array, ci: jax.Array):
        z = (cr, ci)
        if from_transposed:
            # first-axis inverse: in the folded layout the axis is last
            z = execute_inverse(col_plan, z)                # (lp/p, n0)
            z = (jnp.swapaxes(z[0], i0, il), jnp.swapaxes(z[1], i0, il))
        elif transposed_in:
            # planned transposed input is already in the post-exchange-1
            # layout (first axis full, last sharded): no exchange needed
            z = _crop_axis(z, i0, n0)
            z = _fft_axis(col_plan, z, i0, inverse=True)
            z = _pad_axis(z, i0, n0p)
        else:
            z = backend.exchange(z, ax, split=il, concat=i0, p=p)
            z = _crop_axis(z, i0, n0)
            z = _fft_axis(col_plan, z, i0, inverse=True)
            z = _pad_axis(z, i0, n0p)
        z = backend.exchange(z, ax, split=i0, concat=il, p=p)
        z = _crop_axis(z, il, ltrue)                        # drop padding
        for k, mp in reversed(list(enumerate(mid_plans))):  # middle axes
            z = _fft_axis(mp, z, i0 + 1 + k, inverse=True)
        if nd.kind == "c2c":
            return execute_inverse(row_plan, z)
        return rows_irfft(planner, z, nlast)                # c2r last axis

    spec_std = batched_spec(P(ax, *(None,) * (d - 1)), bnd)
    if from_transposed:
        spec_in = batched_spec(P(None, ax), bnd)
    elif transposed_in:
        spec_in = batched_spec(P(*(None,) * (d - 1), ax), bnd)
    else:
        spec_in = spec_std
    out_specs = spec_std if nd.kind == "r2c" else (spec_std, spec_std)
    return shard_map(local, mesh=mesh, in_specs=(spec_in, spec_in),
                     out_specs=out_specs)(c[0], c[1])


# ---------------------------------------------------------------------------
# pencil executor (P3DFFT-style, k mesh axes, ndim >= k+1, batch dims,
# mixed radix)
# ---------------------------------------------------------------------------
#
# Layout convention (forward direction), mesh axes (a0..a_{k-1}) of sizes
# (p0..p_{k-1}) sharding the FIRST k transform axes; 3D/2-axis shown:
#
#   input   (b..., Xp/p0, Yp/p1, Z)    Z-FFT local, pad Z -> Zp (or zh_pad)
#   xchg 1  over a1 (row communicator):   split Z, concat Y
#           (b..., Xp/p0, Yp, Zp/p1)   crop Y, Y-FFT local, re-pad
#   xchg 2  over a0 (column communicator): split Y, concat X
#           (b..., Xp,  Yp/p0, Zp/p1)  crop X, X-FFT local, re-pad
#
# For k > 2 (ndim > 3) the chain continues axis by axis: one exchange per
# adjacent pair of sharded axes, each inside its own communicator, the
# just-transformed axis donating its locality to the next.  Axis paddings:
# axis 0 -> pad_to(., p0); axis j (0 < j < k) -> pad_to(., lcm(p_{j-1},
# p_j)) (input-sharded over p_j, exchange-split over p_{j-1}); non-sharded
# middle axes unpadded; last axis pad_to(., p_{k-1}) (padded_half for r2c).
# Communication stays within row/column(/plane) communicators — the P3DFFT
# advantage the paper cites over slab decomposition.  The inverses retrace
# the same exchanges backwards, so each mesh axis keeps its chosen comm
# backend both ways.


def execute_pencil(nd, x, mesh: jax.sharding.Mesh, planner: Planner, *,
                   chunks: int = 4):
    """Forward pencil transform of an :class:`~repro.core.api.NdPlan`
    (``kind="c2c"``: (re, im) pair in, ``"r2c"``: real array in; any number
    of leading batch dims).  The input's first ``k = len(nd.mesh_axes)``
    transform axes are sharded over the mesh axes in order.  Returns the
    PADDED spectrum pair, global trailing shape ``nd.padded_spectrum_shape``
    sharded ``(None, a0, .., a_{k-2})`` on the leading axes and ``a_{k-1}``
    on the last — crop with ``nd.crop`` for the exact transform."""
    d = len(nd.shape)
    k = len(nd.mesh_axes)
    assert nd.decomp == "pencil" and 2 <= k <= d - 1, (nd.decomp, k, d)
    axs, ps = nd.mesh_axes, nd.mesh_shape
    pair_in = nd.kind == "c2c"
    xr = x[0] if pair_in else x
    bnd = xr.ndim - d
    il = bnd + d - 1
    padded = nd.padded_spectrum_shape
    backends = _pencil_backends(nd, chunks)
    plans = [planner.plan(nd.shape[j], kind="c2c") for j in range(d - 1)]
    plan_last = planner.plan(nd.shape[-1], kind="c2c") if pair_in else None
    if not pair_in:
        _warm_rows_plan(planner, nd.shape[-1])

    pads = [(0, 0)] * xr.ndim
    for j in range(k):                      # mixed radix: pad sharded axes
        pads[bnd + j] = (0, padded[j] - nd.shape[j])
    if any(p != (0, 0) for p in pads):
        x = ((jnp.pad(x[0], pads), jnp.pad(x[1], pads)) if pair_in
             else jnp.pad(x, pads))

    def local(*args):
        if pair_in:
            z = execute(plan_last, args)                    # FFT last axis
        else:
            z = rows_rfft(planner, args[0], nd.shape[-1])   # r2c last axis
        z = _pad_axis(z, il, padded[-1])
        for j in range(k, d - 1):           # unsharded middle axes: local
            z = _fft_axis(plans[j], z, bnd + j)
        donor = il
        for j in range(k - 1, -1, -1):      # the exchange chain
            z = backends[j].exchange(z, axs[j], split=donor, concat=bnd + j,
                                     p=ps[j])
            z = _crop_axis(z, bnd + j, nd.shape[j])
            z = _fft_axis(plans[j], z, bnd + j)             # FFT along j
            z = _pad_axis(z, bnd + j, padded[j])
            donor = bnd + j
        return z

    spec_in = batched_spec(P(*axs, *(None,) * (d - k)), bnd)
    spec_out = batched_spec(_pencil_spectrum_spec(axs, k, d), bnd)
    in_specs = (spec_in, spec_in) if pair_in else (spec_in,)
    args = x if pair_in else (x,)
    return shard_map(local, mesh=mesh, in_specs=in_specs,
                     out_specs=(spec_out, spec_out))(*args)


def execute_pencil_inverse(nd, c: Complex, mesh: jax.sharding.Mesh,
                           planner: Planner, *, chunks: int = 4):
    """Inverse pencil transform: PADDED spectrum pair in (zero padded
    bands), spatial data out — a pair for ``kind="c2c"``, a real array for
    ``"r2c"`` — with the sharded axes still padded to their communicator
    multiples (crop with ``nd.shape``)."""
    d = len(nd.shape)
    k = len(nd.mesh_axes)
    assert nd.decomp == "pencil" and 2 <= k <= d - 1, (nd.decomp, k, d)
    axs, ps = nd.mesh_axes, nd.mesh_shape
    bnd = c[0].ndim - d
    il = bnd + d - 1
    padded = nd.padded_spectrum_shape
    ltrue = nd.spectrum_shape[-1]           # half width for r2c
    backends = _pencil_backends(nd, chunks)
    plans = [planner.plan(nd.shape[j], kind="c2c") for j in range(d - 1)]
    plan_last = planner.plan(nd.shape[-1], kind="c2c") \
        if nd.kind == "c2c" else None
    if nd.kind == "r2c":
        _warm_rows_plan(planner, nd.shape[-1], inverse=True)

    def local(cr: jax.Array, ci: jax.Array):
        z = (cr, ci)
        for j in range(k):                  # retrace the chain backwards
            z = _crop_axis(z, bnd + j, nd.shape[j])
            z = _fft_axis(plans[j], z, bnd + j, inverse=True)
            z = _pad_axis(z, bnd + j, padded[j])
            donor = bnd + j + 1 if j < k - 1 else il
            z = backends[j].exchange(z, axs[j], split=bnd + j, concat=donor,
                                     p=ps[j])
        z = _crop_axis(z, il, ltrue)                        # drop padding
        for j in range(d - 2, k - 1, -1):   # unsharded middle axes
            z = _fft_axis(plans[j], z, bnd + j, inverse=True)
        if nd.kind == "c2c":
            return execute_inverse(plan_last, z)            # inverse last
        return rows_irfft(planner, z, nd.shape[-1])         # c2r last axis

    spec_in = batched_spec(_pencil_spectrum_spec(axs, k, d), bnd)
    spec_out = batched_spec(P(*axs, *(None,) * (d - k)), bnd)
    out_specs = spec_out if nd.kind == "r2c" else (spec_out, spec_out)
    return shard_map(local, mesh=mesh, in_specs=(spec_in, spec_in),
                     out_specs=out_specs)(c[0], c[1])


# ---------------------------------------------------------------------------
# factor1d executor (distributed 1D c2c via the fft_conv factor split)
# ---------------------------------------------------------------------------
#
# The length-N signal is viewed as an (n1, n2) row-major matrix sharded
# over n1 (nd.factors = (n1, n2), both divisible by p — see
# repro.core.fftconv.factor_split).  The paper's own 2D framing of the
# distributed 1D problem:
#
#   stage A: all_to_all -> columns local; DFT along n1; twiddle T[k1, n2]
#   stage B: all_to_all -> rows local;    DFT along n2   => C[k1, k2]
#   unpermute: all_to_all + local transpose => X[n1*k2 + k1], row-sharded
#
# Three exchanges each way.  fft_conv_seq_sharded keeps its own copy of
# stages A/B *without* the unpermute (pointwise products commute with the
# digit permutation, so the convolution skips both transposes); the planned
# front-end needs numpy-exact natural order, hence the third exchange.


def _factor1d_twiddle_block(n1: int, n2: int, axis_name: str, p: int,
                            sign: int, chunk_axis: int) -> Complex:
    """This device's block of ``T[k1, j2] = exp(sign*2*pi*i*k1*j2/(n1*n2))``,
    computed in-graph from ``axis_index`` (O(N/p) per device) rather than
    sliced out of a full O(N) host constant — at the large N where the
    planner picks factor1d over gather-local, a replicated full twiddle
    would cost as much memory as the gather the decomposition avoids.
    ``chunk_axis=1``: all k1, this device's j2 columns (forward);
    ``chunk_axis=0``: this device's k1 rows, all j2 (inverse)."""
    me = jax.lax.axis_index(axis_name)
    if chunk_axis == 1:
        w = n2 // p
        k1 = jax.lax.iota(jnp.float32, n1)[:, None]
        j2 = (me * w + jax.lax.iota(jnp.int32, w)).astype(jnp.float32)[None]
    else:
        w = n1 // p
        k1 = (me * w + jax.lax.iota(jnp.int32, w)) \
            .astype(jnp.float32)[:, None]
        j2 = jax.lax.iota(jnp.float32, n2)[None, :]
    # k1*j2 < N stays exactly representable in f32 for any practical N
    ang = (sign * 2.0 * np.pi / (n1 * n2)) * (k1 * j2)
    return jnp.cos(ang), jnp.sin(ang)


def execute_factor1d(nd, x, mesh: jax.sharding.Mesh, planner: Planner, *,
                     chunks: int = 4) -> Complex:
    """Forward distributed 1D c2c transform of an
    :class:`~repro.core.api.NdPlan` with ``decomp="factor1d"`` ((re, im)
    pair in, sharded over the transform axis; leading batch dims ride
    through).  Returns the natural-order spectrum pair, still sharded over
    the mesh axis."""
    assert nd.decomp == "factor1d" and len(nd.mesh_axes) == 1
    assert nd.kind == "c2c", "factor1d is c2c-only (r2c 1D stays local)"
    ax, p = nd.mesh_axes[0], nd.mesh_shape[0]
    n1, n2 = nd.factors
    assert n1 * n2 == nd.shape[0] and n1 % p == 0 and n2 % p == 0, nd
    bnd = x[0].ndim - 1
    backend = _slab_backend(nd, chunks)
    plan1 = planner.plan(n1, kind="c2c")
    plan2 = planner.plan(n2, kind="c2c")

    def local(xr: jax.Array, xi: jax.Array):
        shape = xr.shape[:-1] + (n1 // p, n2)
        z = (xr.reshape(shape), xi.reshape(shape))
        i1, i2 = z[0].ndim - 2, z[0].ndim - 1
        # stage A: columns local
        z = backend.exchange(z, ax, split=i2, concat=i1, p=p)  # (n1, n2/p)
        z = _fft_axis(plan1, z, i1)                         # DFT along n1
        z = algo.cmul(z, _factor1d_twiddle_block(n1, n2, ax, p, -1,
                                                 chunk_axis=1))
        # stage B: rows local
        z = backend.exchange(z, ax, split=i1, concat=i2, p=p)  # (n1/p, n2)
        z = _fft_axis(plan2, z, i2)                         # DFT along n2
        # unpermute C[k1, k2] -> X[n1*k2 + k1] (natural order, row-sharded)
        z = backend.exchange(z, ax, split=i2, concat=i1, p=p)  # (n1, n2/p)
        z = (jnp.swapaxes(z[0], i1, i2), jnp.swapaxes(z[1], i1, i2))
        flat = z[0].shape[:-2] + (n1 * n2 // p,)
        return z[0].reshape(flat), z[1].reshape(flat)

    spec = batched_spec(P(ax), bnd)
    return shard_map(local, mesh=mesh, in_specs=(spec, spec),
                     out_specs=(spec, spec))(x[0], x[1])


def execute_factor1d_inverse(nd, c: Complex, mesh: jax.sharding.Mesh,
                             planner: Planner, *,
                             chunks: int = 4) -> Complex:
    """Inverse of :func:`execute_factor1d`: natural-order spectrum pair in,
    spatial pair out (both sharded over the mesh axis)."""
    assert nd.decomp == "factor1d" and len(nd.mesh_axes) == 1
    ax, p = nd.mesh_axes[0], nd.mesh_shape[0]
    n1, n2 = nd.factors
    bnd = c[0].ndim - 1
    backend = _slab_backend(nd, chunks)
    plan1 = planner.plan(n1, kind="c2c")
    plan2 = planner.plan(n2, kind="c2c")

    def local(cr: jax.Array, ci: jax.Array):
        shape = cr.shape[:-1] + (n2 // p, n1)
        z = (cr.reshape(shape), ci.reshape(shape))
        i1, i2 = z[0].ndim - 2, z[0].ndim - 1
        # re-permute X[n1*k2 + k1] -> C[k1, k2] (rows local)
        z = (jnp.swapaxes(z[0], i1, i2), jnp.swapaxes(z[1], i1, i2))
        z = backend.exchange(z, ax, split=i1, concat=i2, p=p)  # (n1/p, n2)
        # inverse DFT along k2 (normalized: 1/n2)
        z = _fft_axis(plan2, z, i2, inverse=True)
        # conjugate twiddle T[k1-block, n2]
        z = algo.cmul(z, _factor1d_twiddle_block(n1, n2, ax, p, +1,
                                                 chunk_axis=0))
        # columns local; inverse DFT along k1 (normalized: 1/n1)
        z = backend.exchange(z, ax, split=i2, concat=i1, p=p)  # (n1, n2/p)
        z = _fft_axis(plan1, z, i1, inverse=True)
        # back to the row-sharded natural layout
        z = backend.exchange(z, ax, split=i1, concat=i2, p=p)  # (n1/p, n2)
        flat = z[0].shape[:-2] + (n1 * n2 // p,)
        return z[0].reshape(flat), z[1].reshape(flat)

    spec = batched_spec(P(ax), bnd)
    return shard_map(local, mesh=mesh, in_specs=(spec, spec),
                     out_specs=(spec, spec))(c[0], c[1])


# ---------------------------------------------------------------------------
# deprecated shape-specific shims (build an NdPlan, run the shared executor)
# ---------------------------------------------------------------------------

_DEPRECATED_EMITTED = set()


def _warn_deprecated(name: str) -> None:
    """One DeprecationWarning per entry point per process."""
    if name in _DEPRECATED_EMITTED:
        return
    _DEPRECATED_EMITTED.add(name)
    warnings.warn(
        f"repro.core.dfft.{name} is deprecated; use repro.core.api.plan_nd "
        "and the fftn/ifftn/rfftn/irfftn front-end instead",
        DeprecationWarning, stacklevel=3)


def _shim_plan(shape, kind, mesh, mesh_axes, comm, planner, decomp):
    from .api import plan_nd
    return plan_nd(tuple(shape), kind, mesh=mesh, axes=tuple(mesh_axes),
                   comm=comm, planner=planner, decomp=decomp)


def fft2_slab(x: jax.Array, mesh: jax.sharding.Mesh, axis: str,
              planner: Optional[Planner] = None,
              comm: CommSpec = "collective", chunks: int = 4,
              keep_transposed: bool = False,
              permuted_cols: bool = False):
    """DEPRECATED: distributed 2D r2c FFT (use ``plan_nd`` + ``rfftn``).

    x: real (N, M), sharded (P(axis), None).  Returns (re, im) of shape
    (N, mh_pad) sharded the same way (crop to M//2+1 for the exact rfft2),
    or the transposed (mh_pad/P, N*P) folded layout if ``keep_transposed``
    (saves the whole second communication step when the consumer accepts
    transposed layout).  ``permuted_cols`` skips the column FFT's digit
    transpose (pair with ``ifft2_slab(..., permuted_cols=True)``).
    """
    _warn_deprecated("fft2_slab")
    planner = planner or Planner(backends=("jnp",))
    nd = _shim_plan(x.shape, "r2c", mesh, (axis,), comm, planner, "slab")
    return execute_slab(nd, x, mesh, planner, chunks=chunks,
                        keep_transposed=keep_transposed,
                        permuted_cols=permuted_cols)


def ifft2_slab(c: Complex, mesh: jax.sharding.Mesh, axis: str, m: int,
               planner: Optional[Planner] = None,
               comm: CommSpec = "collective", chunks: int = 4,
               from_transposed: bool = False,
               permuted_cols: bool = False) -> jax.Array:
    """DEPRECATED: inverse of :func:`fft2_slab` back to a real (N, M) array
    (use ``plan_nd`` + ``irfftn``)."""
    _warn_deprecated("ifft2_slab")
    planner = planner or Planner(backends=("jnp",))
    p = mesh.shape[axis]
    n = c[0].shape[1] // p if from_transposed else c[0].shape[0]
    nd = _shim_plan((n, m), "r2c", mesh, (axis,), comm, planner, "slab")
    return execute_slab_inverse(nd, c, mesh, planner, chunks=chunks,
                                from_transposed=from_transposed,
                                permuted_cols=permuted_cols)


def fft3_pencil(x: Complex, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                planner: Optional[Planner] = None,
                comm: CommSpec = "collective", chunks: int = 4) -> Complex:
    """DEPRECATED: 3D c2c pencil FFT of (X, Y, Z) sharded
    (P(ax0), P(ax1), None) (use ``plan_nd`` + ``fftn``).  Output sharded
    (None, P(ax0), P(ax1)).  ``comm`` may be one spec for both
    communicators, a per-axis pair/dict, ``"auto"`` or ``"measure"``."""
    _warn_deprecated("fft3_pencil")
    planner = planner or Planner(backends=("jnp",))
    nd = _shim_plan(x[0].shape, "c2c", mesh, axes, comm, planner, "pencil")
    return execute_pencil(nd, x, mesh, planner, chunks=chunks)


def ifft3_pencil(c: Complex, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                 planner: Optional[Planner] = None,
                 comm: CommSpec = "collective", chunks: int = 4) -> Complex:
    """DEPRECATED: inverse of :func:`fft3_pencil` (use ``plan_nd`` +
    ``ifftn``)."""
    _warn_deprecated("ifft3_pencil")
    planner = planner or Planner(backends=("jnp",))
    nd = _shim_plan(c[0].shape, "c2c", mesh, axes, comm, planner, "pencil")
    return execute_pencil_inverse(nd, c, mesh, planner, chunks=chunks)


def rfft3_pencil(x: jax.Array, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                 planner: Optional[Planner] = None,
                 comm: CommSpec = "collective", chunks: int = 4) -> Complex:
    """DEPRECATED: 3D r2c pencil FFT of a real (X, Y, Z) array (use
    ``plan_nd`` + ``rfftn``).  Output: (re, im) of global shape
    (X, Y, zh_pad) sharded (None, P(ax0), P(ax1)) — crop the last axis to
    Z//2+1 for the exact ``numpy.fft.rfftn``."""
    _warn_deprecated("rfft3_pencil")
    planner = planner or Planner(backends=("jnp",))
    nd = _shim_plan(x.shape, "r2c", mesh, axes, comm, planner, "pencil")
    return execute_pencil(nd, x, mesh, planner, chunks=chunks)


def irfft3_pencil(c: Complex, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                  nz: int, planner: Optional[Planner] = None,
                  comm: CommSpec = "collective",
                  chunks: int = 4) -> jax.Array:
    """DEPRECATED: inverse of :func:`rfft3_pencil` back to a real (X, Y, Z)
    array (use ``plan_nd`` + ``irfftn``).  Takes the *uncropped* padded
    spectrum plus the original Z length ``nz``."""
    _warn_deprecated("irfft3_pencil")
    planner = planner or Planner(backends=("jnp",))
    nx, ny = c[0].shape[0], c[0].shape[1]
    nd = _shim_plan((nx, ny, nz), "r2c", mesh, axes, comm, planner, "pencil")
    return execute_pencil_inverse(nd, c, mesh, planner, chunks=chunks)


# ---------------------------------------------------------------------------
# distribute / collect (the paper's `scatter` collective setup step)
# ---------------------------------------------------------------------------


def distribute(x: jax.Array, mesh: jax.sharding.Mesh, axis: str) -> jax.Array:
    """Scatter a host/global matrix into row slabs over ``axis`` (the paper's
    hpx scatter collective before the FFT)."""
    from jax.sharding import NamedSharding
    return jax.device_put(x, NamedSharding(mesh, P(axis, None)))


def collect(x, plan=None) -> np.ndarray:
    """Gather slabs back to a single host array (paper: gather/concat).

    With an :class:`~repro.core.api.NdPlan` the padded collective bands are
    cropped away (``plan.crop``), so callers get the exact transform instead
    of having to know the padded column count.  Pairs are cropped per
    member."""
    if isinstance(x, tuple):
        return tuple(collect(a, plan) for a in x)
    out = np.asarray(jax.device_get(x))
    if plan is not None:
        out = out[(Ellipsis,) + plan.crop]
    return out
