"""Distributed multidimensional FFT on a device mesh (the paper's §3.2/§5.3).

Slab decomposition over one mesh axis, pencil decomposition over two.  All
data movement is EXPLICIT collectives inside ``shard_map`` — the paper's
central design decision ("relying on the implicit communication HPX allows
with AGAS does not make sense; instead we use the HPX equivalents of the MPI
collective operations").

This module holds the *executors*: given an :class:`repro.core.api.NdPlan`
(the pure-data recipe produced by :func:`repro.core.api.plan_nd`), the
``execute_slab`` / ``execute_pencil`` pairs run the decomposed transform on
a live mesh.  The planning — which decomposition, which mesh-axis
assignment, which exchange backend — lives in :mod:`repro.core.api`; the
exchange strategies themselves live in :mod:`repro.core.comm`.

One shared pad-and-crop layer serves every path:

* r2c half spectra are zero-padded to the collective-divisible width
  (``padded_half``), the convention the 2D slab path always had;
* **mixed-radix mesh shapes** — transform axes not divisible by their
  communicator — are handled by zero-padding the axis up to the next
  multiple, cropping to the true length just before the axis is transformed,
  and re-padding after, so the padded band stays exactly zero through every
  exchange and is cropped once at the end (``NdPlan.crop``);
* **leading batch dims** ride through every executor via the batched
  shard_map spec helper (:func:`repro.core.compat.batched_spec`) shared
  with :func:`repro.core.fftconv.fft_conv_seq_sharded`.

Algorithm (slab, 2D r2c, row-major N x M, P devices; paper's five steps):

  1. local r2c FFTs along contiguous rows          (N/P, Mh)
  2. COMMUNICATE: all_to_all column slabs          -> (N, Mh/P)  [rearrange
     = split into N_locs parts + concat, fused into the tiled collective]
  3. transpose AFTER communication (paper's choice) -> (Mh/P, N)
  4. local c2c FFTs along (now contiguous) columns
  5. COMMUNICATE back + rearrange to original layout (N/P, Mh)

Pencil decomposition (P3DFFT-style, 2D mesh) has full parity with slab.

The historical shape-specific entry points — ``fft2_slab``/``ifft2_slab``
and the four ``*_pencil`` functions — remain as thin DEPRECATED shims that
build an ``NdPlan`` internally and call the shared executors; new code
should go through :func:`repro.core.api.plan_nd` and the ``fftn`` family.
"""

from __future__ import annotations

import warnings
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import algo
from .comm import (COMM_BACKENDS, CommBackend, CommSpec, get_backend,
                   measure_comm_pencil, measure_comm_slab, pad_to,
                   padded_half, plan_comm, plan_comm_pencil,
                   resolve_axis_backends)
from .compat import batched_spec, shard_map
from .plan import Plan, Planner, execute, execute_inverse

Complex = algo.Complex

__all__ = [
    "COMM_BACKENDS", "padded_half", "pad_to", "plan_comm", "plan_comm_pencil",
    "measure_comm_slab", "measure_comm_pencil",
    "rows_rfft", "rows_irfft", "hermitian_extend_last",
    "execute_slab", "execute_slab_inverse",
    "execute_pencil", "execute_pencil_inverse",
    "fft2_slab", "ifft2_slab",
    "fft3_pencil", "ifft3_pencil", "rfft3_pencil", "irfft3_pencil",
    "distribute", "collect",
]


# ---------------------------------------------------------------------------
# shared pad-and-crop layer (every decomposition path goes through these)
# ---------------------------------------------------------------------------


def _pad_axis(c: Complex, axis: int, target: int) -> Complex:
    """Zero-pad one axis of an (re, im) pair up to ``target`` entries."""
    pad = target - c[0].shape[axis]
    if pad <= 0:
        return c
    widths = [(0, 0)] * c[0].ndim
    widths[axis] = (0, pad)
    return jnp.pad(c[0], widths), jnp.pad(c[1], widths)


def _crop_axis(c: Complex, axis: int, n: int) -> Complex:
    """Crop one axis of a pair back to its true length ``n``."""
    if c[0].shape[axis] == n:
        return c
    return (jax.lax.slice_in_dim(c[0], 0, n, axis=axis),
            jax.lax.slice_in_dim(c[1], 0, n, axis=axis))


def _fft_axis(plan: Plan, c: Complex, axis: int, inverse: bool = False
              ) -> Complex:
    """c2c transform along one (fully local) axis of a pair."""
    if axis == c[0].ndim - 1 or axis == -1:
        return execute_inverse(plan, c) if inverse else execute(plan, c)
    ct = (jnp.moveaxis(c[0], axis, -1), jnp.moveaxis(c[1], axis, -1))
    zt = execute_inverse(plan, ct) if inverse else execute(plan, ct)
    return jnp.moveaxis(zt[0], -1, axis), jnp.moveaxis(zt[1], -1, axis)


def hermitian_extend_last(c: Complex, n: int) -> Complex:
    """Rebuild the full length-``n`` spectrum from the half spectrum of a
    real signal along the last axis: ``F[k] = conj(F[n-k])`` for k > n//2.
    Valid whenever every other axis is already in its real/spatial form."""
    mh = n // 2 + 1
    idx = np.arange(n - mh, 0, -1)          # tail k = mh..n-1  <-  n-k
    return (jnp.concatenate([c[0], c[0][..., idx]], -1),
            jnp.concatenate([c[1], -c[1][..., idx]], -1))


def rows_rfft(planner: Planner, x: jax.Array, n: int) -> Complex:
    """r2c FFT along the last axis for ANY length: even lengths use the
    packed real codelet path, odd lengths fall back to a c2c transform of
    the real signal cropped to the half spectrum."""
    if n % 2 == 0:
        return execute(planner.plan(n, kind="r2c"), x)
    re, im = execute(planner.plan(n, kind="c2c"), (x, jnp.zeros_like(x)))
    return re[..., : n // 2 + 1], im[..., : n // 2 + 1]


def rows_irfft(planner: Planner, c: Complex, n: int) -> jax.Array:
    """c2r inverse of :func:`rows_rfft` (input ``(..., n//2+1)``)."""
    if n % 2 == 0:
        return execute(planner.plan(n, kind="c2r"), c)
    full = hermitian_extend_last(c, n)
    return execute_inverse(planner.plan(n, kind="c2c"), full)[0]


def _warm_rows_plan(planner: Planner, n: int, inverse: bool = False) -> None:
    """Pre-plan the 1D stage :func:`rows_rfft` / :func:`rows_irfft` will
    request, OUTSIDE any traced function — their trace-time lookups then hit
    the planner's wisdom cache without triggering a wisdom write."""
    if n % 2 == 0:
        planner.plan(n, kind="c2r" if inverse else "r2c")
    else:
        planner.plan(n, kind="c2c")


def _local_rows_rfft(x: jax.Array, plan: Plan, mh_pad: int) -> Complex:
    """r2c FFT along the last axis + zero-pad to the collective-divisible
    width (works for any number of leading batch axes)."""
    re, im = execute(plan, x)
    return _pad_axis((re, im), -1, mh_pad)


def _slab_backend(nd, chunks: int) -> CommBackend:
    return get_backend(nd.comm[0] if nd.comm else "collective", chunks=chunks)


def _pencil_backends(nd, chunks: int) -> Tuple[CommBackend, CommBackend]:
    return resolve_axis_backends(nd.comm, nd.mesh_axes, chunks=chunks)


# ---------------------------------------------------------------------------
# slab executor (1 mesh axis, ndim >= 2, leading batch dims, mixed radix)
# ---------------------------------------------------------------------------
#
# Layout (forward, transform shape (n0, ..., nlast), P devices over `ax`):
#
#   input   (b..., n0p/P, ..., nlast)   last-axis FFT (r2c or c2c) local,
#                                       then every middle axis, then pad the
#                                       spectrum's last axis to lp
#   xchg    split last, concat first -> (b..., n0p, ..., lp/P)
#   ax0 FFT crop n0p -> n0, transform, re-pad to n0p
#   xchg    split first, concat last -> (b..., n0p/P, ..., lp)
#
# n0p = pad_to(n0, P); lp = padded_half(nlast, P) for r2c, pad_to(nlast, P)
# for c2c.  The padded bands are exactly zero throughout (zero columns stay
# zero under FFTs along other axes), so `NdPlan.crop` recovers the exact
# spectrum.


def execute_slab(nd, x, mesh: jax.sharding.Mesh, planner: Planner, *,
                 chunks: int = 4, keep_transposed: bool = False,
                 permuted_cols: bool = False):
    """Forward slab transform of an :class:`~repro.core.api.NdPlan`.

    ``x``: real array for ``kind="r2c"``, (re, im) pair for ``"c2c"``, with
    any number of leading batch dims.  Returns the PADDED spectrum pair
    (global trailing shape ``nd.padded_spectrum_shape``), sharded over the
    first transform axis — crop with ``nd.crop`` for the exact transform.

    ``keep_transposed`` / ``permuted_cols`` are the 2D-only layout
    optimizations of the historical ``fft2_slab`` (skip the second exchange
    / skip the column digit transpose).
    """
    d = len(nd.shape)
    assert nd.decomp == "slab" and len(nd.mesh_axes) == 1
    if keep_transposed or permuted_cols:
        assert d == 2, "transposed/permuted layouts are 2D-only"
    ax, p = nd.mesh_axes[0], nd.mesh_shape[0]
    pair_in = nd.kind == "c2c"
    xr = x[0] if pair_in else x
    bnd = xr.ndim - d
    i0, il = bnd, bnd + d - 1
    n0, nlast = nd.shape[0], nd.shape[-1]
    n0p = pad_to(n0, p)
    lp = nd.padded_spectrum_shape[-1]
    backend = _slab_backend(nd, chunks)

    if keep_transposed and n0p != n0:
        raise ValueError("keep_transposed requires shape[0] divisible by "
                         "the mesh axis (mixed radix keeps both exchanges)")
    row_plan = planner.plan(nlast, kind="c2c") if pair_in else None
    if not pair_in:
        _warm_rows_plan(planner, nlast)
    mid_plans = [planner.plan(nd.shape[k], kind="c2c")
                 for k in range(1, d - 1)]
    col_plan = planner.plan(n0, kind="c2c", permuted=permuted_cols)

    if n0p != n0:                       # mixed radix: zero-pad sharded axis
        widths = [(0, 0)] * xr.ndim
        widths[i0] = (0, n0p - n0)
        x = ((jnp.pad(x[0], widths), jnp.pad(x[1], widths)) if pair_in
             else jnp.pad(x, widths))

    def local(*args):
        if pair_in:
            y = execute(row_plan, args)                     # c2c last axis
            y = _pad_axis(y, il, lp)
        else:
            y = rows_rfft(planner, args[0], nlast)          # r2c last axis
            y = _pad_axis(y, il, lp)
        for k, mp in enumerate(mid_plans):                  # middle axes
            y = _fft_axis(mp, y, i0 + 1 + k)
        y = backend.exchange(y, ax, split=il, concat=i0, p=p)
        y = _crop_axis(y, i0, n0)                           # mixed radix
        y = _fft_axis(col_plan, y, i0)                      # first axis
        if keep_transposed:     # 2D: hand back the transposed local layout
            return jnp.swapaxes(y[0], i0, il), jnp.swapaxes(y[1], i0, il)
        y = _pad_axis(y, i0, n0p)
        return backend.exchange(y, ax, split=i0, concat=il, p=p)

    spec_in = batched_spec(P(ax, *(None,) * (d - 1)), bnd)
    spec_out = batched_spec(
        P(None, ax) if keep_transposed else P(ax, *(None,) * (d - 1)), bnd)
    in_specs = (spec_in, spec_in) if pair_in else (spec_in,)
    args = x if pair_in else (x,)
    return shard_map(local, mesh=mesh, in_specs=in_specs,
                     out_specs=(spec_out, spec_out))(*args)


def execute_slab_inverse(nd, c: Complex, mesh: jax.sharding.Mesh,
                         planner: Planner, *, chunks: int = 4,
                         from_transposed: bool = False,
                         permuted_cols: bool = False):
    """Inverse slab transform: consumes the PADDED spectrum pair produced by
    :func:`execute_slab` (zero padded bands) and returns the spatial array —
    real for ``kind="r2c"``, a pair for ``"c2c"`` — with the first transform
    axis still padded to ``pad_to(n0, p)`` (crop with ``nd.shape[0]``)."""
    d = len(nd.shape)
    assert nd.decomp == "slab" and len(nd.mesh_axes) == 1
    if from_transposed or permuted_cols:
        assert d == 2, "transposed/permuted layouts are 2D-only"
    ax, p = nd.mesh_axes[0], nd.mesh_shape[0]
    bnd = c[0].ndim - d
    i0, il = bnd, bnd + d - 1
    n0, nlast = nd.shape[0], nd.shape[-1]
    n0p = pad_to(n0, p)
    lp = nd.padded_spectrum_shape[-1]
    ltrue = nd.spectrum_shape[-1]       # mh for r2c, nlast for c2c
    backend = _slab_backend(nd, chunks)
    col_plan = planner.plan(n0, kind="c2c", permuted=permuted_cols)
    mid_plans = [planner.plan(nd.shape[k], kind="c2c")
                 for k in range(1, d - 1)]
    row_plan = planner.plan(nlast, kind="c2c") if nd.kind == "c2c" else None
    if nd.kind == "r2c":
        _warm_rows_plan(planner, nlast, inverse=True)

    if from_transposed and n0p != n0:
        raise ValueError("from_transposed requires shape[0] divisible by "
                         "the mesh axis")

    def local(cr: jax.Array, ci: jax.Array):
        z = (cr, ci)
        if from_transposed:
            # first-axis inverse: in the transposed layout the axis is last
            z = execute_inverse(col_plan, z)                # (lp/p, n0)
            z = (jnp.swapaxes(z[0], i0, il), jnp.swapaxes(z[1], i0, il))
        else:
            z = backend.exchange(z, ax, split=il, concat=i0, p=p)
            z = _crop_axis(z, i0, n0)
            z = _fft_axis(col_plan, z, i0, inverse=True)
            z = _pad_axis(z, i0, n0p)
        z = backend.exchange(z, ax, split=i0, concat=il, p=p)
        z = _crop_axis(z, il, ltrue)                        # drop padding
        for k, mp in reversed(list(enumerate(mid_plans))):  # middle axes
            z = _fft_axis(mp, z, i0 + 1 + k, inverse=True)
        if nd.kind == "c2c":
            return execute_inverse(row_plan, z)
        return rows_irfft(planner, z, nlast)                # c2r last axis

    spec_std = batched_spec(P(ax, *(None,) * (d - 1)), bnd)
    spec_in = batched_spec(P(None, ax), bnd) if from_transposed else spec_std
    out_specs = spec_std if nd.kind == "r2c" else (spec_std, spec_std)
    return shard_map(local, mesh=mesh, in_specs=(spec_in, spec_in),
                     out_specs=out_specs)(c[0], c[1])


# ---------------------------------------------------------------------------
# pencil executor (P3DFFT-style, 2D mesh, ndim == 3, batch dims, mixed radix)
# ---------------------------------------------------------------------------
#
# Layout convention (forward direction), mesh axes (ax0, ax1) = (p0, p1):
#
#   input   (b..., Xp/p0, Yp/p1, Z)    Z-FFT local, pad Z -> Zp (or zh_pad)
#   xchg 1  over ax1 (row communicator):   split Z, concat Y
#           (b..., Xp/p0, Yp, Zp/p1)   crop Y, Y-FFT local, re-pad
#   xchg 2  over ax0 (column communicator): split Y, concat X
#           (b..., Xp,  Yp/p0, Zp/p1)  crop X, X-FFT local, re-pad
#
# Xp = pad_to(X, p0); Yp = pad_to(Y, lcm-multiple of both communicators);
# Zp = pad_to(Z, p1) for c2c, padded_half(Z, p1) for r2c.  Communication
# stays within row/column communicators — the P3DFFT advantage the paper
# cites over slab decomposition.  The inverses retrace the same exchanges
# backwards, so each mesh axis keeps its chosen comm backend both ways.


def execute_pencil(nd, x, mesh: jax.sharding.Mesh, planner: Planner, *,
                   chunks: int = 4):
    """Forward pencil transform of an :class:`~repro.core.api.NdPlan`
    (``kind="c2c"``: (re, im) pair in, ``"r2c"``: real array in; any number
    of leading batch dims).  Returns the PADDED spectrum pair, global
    trailing shape ``nd.padded_spectrum_shape`` sharded
    ``(None, ax0, ax1)`` — crop with ``nd.crop`` for the exact transform."""
    assert nd.decomp == "pencil" and len(nd.mesh_axes) == 2
    assert len(nd.shape) == 3, "pencil decomposition is 3D"
    ax0, ax1 = nd.mesh_axes
    p0, p1 = nd.mesh_shape
    pair_in = nd.kind == "c2c"
    xr = x[0] if pair_in else x
    bnd = xr.ndim - 3
    ix, iy, iz = bnd, bnd + 1, bnd + 2
    nx, ny, nz = nd.shape
    xp, yp, zp = nd.padded_spectrum_shape   # (Xp, Yp, Zp-or-zh_pad)
    b0, b1 = _pencil_backends(nd, chunks)
    plan_y = planner.plan(ny, kind="c2c")
    plan_x = planner.plan(nx, kind="c2c")
    plan_z = planner.plan(nz, kind="c2c") if pair_in else None
    if not pair_in:
        _warm_rows_plan(planner, nz)

    pads = [(0, 0)] * xr.ndim
    pads[ix] = (0, xp - nx)
    pads[iy] = (0, yp - ny)
    if any(p != (0, 0) for p in pads):      # mixed radix: pad sharded axes
        x = ((jnp.pad(x[0], pads), jnp.pad(x[1], pads)) if pair_in
             else jnp.pad(x, pads))

    def local(*args):
        if pair_in:
            z = execute(plan_z, args)                       # FFT along Z
            z = _pad_axis(z, iz, zp)
        else:
            z = rows_rfft(planner, args[0], nz)             # r2c along Z
            z = _pad_axis(z, iz, zp)
        z = b1.exchange(z, ax1, split=iz, concat=iy, p=p1)  # Y local
        z = _crop_axis(z, iy, ny)
        z = _fft_axis(plan_y, z, iy)                        # FFT along Y
        z = _pad_axis(z, iy, yp)
        z = b0.exchange(z, ax0, split=iy, concat=ix, p=p0)  # X local
        z = _crop_axis(z, ix, nx)
        z = _fft_axis(plan_x, z, ix)                        # FFT along X
        return _pad_axis(z, ix, xp)

    spec_in = batched_spec(P(ax0, ax1, None), bnd)
    spec_out = batched_spec(P(None, ax0, ax1), bnd)
    in_specs = (spec_in, spec_in) if pair_in else (spec_in,)
    args = x if pair_in else (x,)
    return shard_map(local, mesh=mesh, in_specs=in_specs,
                     out_specs=(spec_out, spec_out))(*args)


def execute_pencil_inverse(nd, c: Complex, mesh: jax.sharding.Mesh,
                           planner: Planner, *, chunks: int = 4):
    """Inverse pencil transform: PADDED spectrum pair in (zero padded
    bands), spatial data out — a pair for ``kind="c2c"``, a real array for
    ``"r2c"`` — with X/Y still padded to their communicator multiples
    (crop with ``nd.shape``)."""
    assert nd.decomp == "pencil" and len(nd.mesh_axes) == 2
    ax0, ax1 = nd.mesh_axes
    p0, p1 = nd.mesh_shape
    bnd = c[0].ndim - 3
    ix, iy, iz = bnd, bnd + 1, bnd + 2
    nx, ny, nz = nd.shape
    xp, yp, zp = nd.padded_spectrum_shape
    ztrue = nd.spectrum_shape[-1]           # zh for r2c, nz for c2c
    b0, b1 = _pencil_backends(nd, chunks)
    plan_y = planner.plan(ny, kind="c2c")
    plan_x = planner.plan(nx, kind="c2c")
    plan_z = planner.plan(nz, kind="c2c") if nd.kind == "c2c" else None
    if nd.kind == "r2c":
        _warm_rows_plan(planner, nz, inverse=True)

    def local(cr: jax.Array, ci: jax.Array):
        z = (cr, ci)                                        # (Xp, Yp/p0, Zp/p1)
        z = _crop_axis(z, ix, nx)
        z = _fft_axis(plan_x, z, ix, inverse=True)          # inverse X
        z = _pad_axis(z, ix, xp)
        z = b0.exchange(z, ax0, split=ix, concat=iy, p=p0)  # (Xp/p0, Yp, ..)
        z = _crop_axis(z, iy, ny)
        z = _fft_axis(plan_y, z, iy, inverse=True)          # inverse Y
        z = _pad_axis(z, iy, yp)
        z = b1.exchange(z, ax1, split=iy, concat=iz, p=p1)  # (.., Yp/p1, Zp)
        z = _crop_axis(z, iz, ztrue)                        # drop padding
        if nd.kind == "c2c":
            return execute_inverse(plan_z, z)               # inverse Z
        return rows_irfft(planner, z, nz)                   # c2r along Z

    spec_in = batched_spec(P(None, ax0, ax1), bnd)
    spec_out = batched_spec(P(ax0, ax1, None), bnd)
    out_specs = spec_out if nd.kind == "r2c" else (spec_out, spec_out)
    return shard_map(local, mesh=mesh, in_specs=(spec_in, spec_in),
                     out_specs=out_specs)(c[0], c[1])


# ---------------------------------------------------------------------------
# deprecated shape-specific shims (build an NdPlan, run the shared executor)
# ---------------------------------------------------------------------------

_DEPRECATED_EMITTED = set()


def _warn_deprecated(name: str) -> None:
    """One DeprecationWarning per entry point per process."""
    if name in _DEPRECATED_EMITTED:
        return
    _DEPRECATED_EMITTED.add(name)
    warnings.warn(
        f"repro.core.dfft.{name} is deprecated; use repro.core.api.plan_nd "
        "and the fftn/ifftn/rfftn/irfftn front-end instead",
        DeprecationWarning, stacklevel=3)


def _shim_plan(shape, kind, mesh, mesh_axes, comm, planner, decomp):
    from .api import plan_nd
    return plan_nd(tuple(shape), kind, mesh=mesh, axes=tuple(mesh_axes),
                   comm=comm, planner=planner, decomp=decomp)


def fft2_slab(x: jax.Array, mesh: jax.sharding.Mesh, axis: str,
              planner: Optional[Planner] = None,
              comm: CommSpec = "collective", chunks: int = 4,
              keep_transposed: bool = False,
              permuted_cols: bool = False):
    """DEPRECATED: distributed 2D r2c FFT (use ``plan_nd`` + ``rfftn``).

    x: real (N, M), sharded (P(axis), None).  Returns (re, im) of shape
    (N, mh_pad) sharded the same way (crop to M//2+1 for the exact rfft2),
    or the transposed (mh_pad/P, N*P) folded layout if ``keep_transposed``
    (saves the whole second communication step when the consumer accepts
    transposed layout).  ``permuted_cols`` skips the column FFT's digit
    transpose (pair with ``ifft2_slab(..., permuted_cols=True)``).
    """
    _warn_deprecated("fft2_slab")
    planner = planner or Planner(backends=("jnp",))
    nd = _shim_plan(x.shape, "r2c", mesh, (axis,), comm, planner, "slab")
    return execute_slab(nd, x, mesh, planner, chunks=chunks,
                        keep_transposed=keep_transposed,
                        permuted_cols=permuted_cols)


def ifft2_slab(c: Complex, mesh: jax.sharding.Mesh, axis: str, m: int,
               planner: Optional[Planner] = None,
               comm: CommSpec = "collective", chunks: int = 4,
               from_transposed: bool = False,
               permuted_cols: bool = False) -> jax.Array:
    """DEPRECATED: inverse of :func:`fft2_slab` back to a real (N, M) array
    (use ``plan_nd`` + ``irfftn``)."""
    _warn_deprecated("ifft2_slab")
    planner = planner or Planner(backends=("jnp",))
    p = mesh.shape[axis]
    n = c[0].shape[1] // p if from_transposed else c[0].shape[0]
    nd = _shim_plan((n, m), "r2c", mesh, (axis,), comm, planner, "slab")
    return execute_slab_inverse(nd, c, mesh, planner, chunks=chunks,
                                from_transposed=from_transposed,
                                permuted_cols=permuted_cols)


def fft3_pencil(x: Complex, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                planner: Optional[Planner] = None,
                comm: CommSpec = "collective", chunks: int = 4) -> Complex:
    """DEPRECATED: 3D c2c pencil FFT of (X, Y, Z) sharded
    (P(ax0), P(ax1), None) (use ``plan_nd`` + ``fftn``).  Output sharded
    (None, P(ax0), P(ax1)).  ``comm`` may be one spec for both
    communicators, a per-axis pair/dict, ``"auto"`` or ``"measure"``."""
    _warn_deprecated("fft3_pencil")
    planner = planner or Planner(backends=("jnp",))
    nd = _shim_plan(x[0].shape, "c2c", mesh, axes, comm, planner, "pencil")
    return execute_pencil(nd, x, mesh, planner, chunks=chunks)


def ifft3_pencil(c: Complex, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                 planner: Optional[Planner] = None,
                 comm: CommSpec = "collective", chunks: int = 4) -> Complex:
    """DEPRECATED: inverse of :func:`fft3_pencil` (use ``plan_nd`` +
    ``ifftn``)."""
    _warn_deprecated("ifft3_pencil")
    planner = planner or Planner(backends=("jnp",))
    nd = _shim_plan(c[0].shape, "c2c", mesh, axes, comm, planner, "pencil")
    return execute_pencil_inverse(nd, c, mesh, planner, chunks=chunks)


def rfft3_pencil(x: jax.Array, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                 planner: Optional[Planner] = None,
                 comm: CommSpec = "collective", chunks: int = 4) -> Complex:
    """DEPRECATED: 3D r2c pencil FFT of a real (X, Y, Z) array (use
    ``plan_nd`` + ``rfftn``).  Output: (re, im) of global shape
    (X, Y, zh_pad) sharded (None, P(ax0), P(ax1)) — crop the last axis to
    Z//2+1 for the exact ``numpy.fft.rfftn``."""
    _warn_deprecated("rfft3_pencil")
    planner = planner or Planner(backends=("jnp",))
    nd = _shim_plan(x.shape, "r2c", mesh, axes, comm, planner, "pencil")
    return execute_pencil(nd, x, mesh, planner, chunks=chunks)


def irfft3_pencil(c: Complex, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                  nz: int, planner: Optional[Planner] = None,
                  comm: CommSpec = "collective",
                  chunks: int = 4) -> jax.Array:
    """DEPRECATED: inverse of :func:`rfft3_pencil` back to a real (X, Y, Z)
    array (use ``plan_nd`` + ``irfftn``).  Takes the *uncropped* padded
    spectrum plus the original Z length ``nz``."""
    _warn_deprecated("irfft3_pencil")
    planner = planner or Planner(backends=("jnp",))
    nx, ny = c[0].shape[0], c[0].shape[1]
    nd = _shim_plan((nx, ny, nz), "r2c", mesh, axes, comm, planner, "pencil")
    return execute_pencil_inverse(nd, c, mesh, planner, chunks=chunks)


# ---------------------------------------------------------------------------
# distribute / collect (the paper's `scatter` collective setup step)
# ---------------------------------------------------------------------------


def distribute(x: jax.Array, mesh: jax.sharding.Mesh, axis: str) -> jax.Array:
    """Scatter a host/global matrix into row slabs over ``axis`` (the paper's
    hpx scatter collective before the FFT)."""
    from jax.sharding import NamedSharding
    return jax.device_put(x, NamedSharding(mesh, P(axis, None)))


def collect(x, plan=None) -> np.ndarray:
    """Gather slabs back to a single host array (paper: gather/concat).

    With an :class:`~repro.core.api.NdPlan` the padded collective bands are
    cropped away (``plan.crop``), so callers get the exact transform instead
    of having to know the padded column count.  Pairs are cropped per
    member."""
    if isinstance(x, tuple):
        return tuple(collect(a, plan) for a in x)
    out = np.asarray(jax.device_get(x))
    if plan is not None:
        out = out[(Ellipsis,) + plan.crop]
    return out
