"""Distributed multidimensional FFT on a device mesh (the paper's §3.2/§5.3).

Slab decomposition over one mesh axis, pencil decomposition over two.  All
data movement is EXPLICIT collectives inside ``shard_map`` — the paper's
central design decision ("relying on the implicit communication HPX allows
with AGAS does not make sense; instead we use the HPX equivalents of the MPI
collective operations").

Communication backends (paper §5.3, Fig. 6):

* ``collective`` — one monolithic ``jax.lax.all_to_all`` per redistribution
  (HPX collectives over the MPI parcelport; XLA's stock schedule).
* ``pipelined`` — the redistribution is split into ``chunks`` column groups;
  chunk c's all_to_all is issued while chunk c+1's row-FFT computes, a
  software pipeline that hides ICI latency behind MXU work.  This is the
  TPU-native analogue of the LCI parcelport's 4-5x communication speedup:
  same bytes, less *exposed* time.
* ``agas`` — all-gather-then-slice: every locality materializes the full
  matrix and takes its slice, emulating the redundant data movement of
  implicit AGAS addressing.  Implemented to *measure* the overhead the paper
  plots (Fig. 1, dark blue), not to be used.

Algorithm (slab, 2D r2c, row-major N x M, P devices; paper's five steps):

  1. local r2c FFTs along contiguous rows          (N/P, Mh)
  2. COMMUNICATE: all_to_all column slabs          -> (N, Mh/P)  [rearrange
     = split into N_locs parts + concat, fused into the tiled collective]
  3. transpose AFTER communication (paper's choice) -> (Mh/P, N)
  4. local c2c FFTs along (now contiguous) columns
  5. COMMUNICATE back + rearrange to original layout (N/P, Mh)

The transform matches ``numpy.fft.rfft2`` zero-padded to the padded column
count; ``Mh`` is padded to a multiple of P for collective divisibility and
cropped by the caller-facing wrappers.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import algo
from .plan import Plan, Planner, execute

Complex = algo.Complex

COMM_BACKENDS = ("collective", "pipelined", "agas")


def padded_half(m: int, p: int) -> int:
    """Column count after r2c (m//2+1) padded up to a multiple of p."""
    mh = m // 2 + 1
    return ((mh + p - 1) // p) * p


# ---------------------------------------------------------------------------
# local building blocks (run per-device inside shard_map)
# ---------------------------------------------------------------------------


def _local_rows_rfft(x: jax.Array, plan: Plan, mh_pad: int) -> Complex:
    """r2c FFT along rows + zero-pad columns to the collective-divisible width."""
    re, im = execute(plan, x)
    pad = mh_pad - re.shape[-1]
    if pad:
        re = jnp.pad(re, ((0, 0), (0, pad)))
        im = jnp.pad(im, ((0, 0), (0, pad)))
    return re, im


def _a2a(c: Complex, axis_name: str, split: int, concat: int) -> Complex:
    f = functools.partial(jax.lax.all_to_all, axis_name=axis_name,
                          split_axis=split, concat_axis=concat, tiled=True)
    return f(c[0]), f(c[1])


# ---------------------------------------------------------------------------
# slab-decomposed 2D r2c FFT
# ---------------------------------------------------------------------------


def fft2_slab(x: jax.Array, mesh: jax.sharding.Mesh, axis: str,
              planner: Optional[Planner] = None,
              comm: str = "collective", chunks: int = 4,
              keep_transposed: bool = False,
              permuted_cols: bool = False):
    """Distributed 2D r2c FFT.

    x: real (N, M), sharded (P(axis), None).  Returns (re, im) of shape
    (N, mh_pad) sharded the same way (crop to M//2+1 for the exact rfft2),
    or (mh_pad, N) sharded over rows if ``keep_transposed`` (saves the whole
    second communication step when the consumer accepts transposed layout —
    e.g. convolution pipelines that come straight back).

    ``permuted_cols`` skips the column FFT's digit transpose (output columns
    arrive in four-step permuted frequency order — valid for pointwise
    spectral consumers; pair with ``ifft2_slab(..., permuted_cols=True)``).
    One fewer memory pass per column transform.
    """
    planner = planner or Planner(backends=("jnp",))
    n, m = x.shape
    p = mesh.shape[axis]
    mh_pad = padded_half(m, p)
    row_plan = planner.plan(m, kind="r2c")
    col_plan = planner.plan(n, kind="c2c", permuted=permuted_cols)

    def local(xl: jax.Array) -> Tuple[jax.Array, jax.Array]:
        y = _local_rows_rfft(xl, row_plan, mh_pad)              # (n/p, mh_pad)
        if comm == "collective":
            y = _a2a(y, axis, split=1, concat=0)                # (n, mh_pad/p)
        elif comm == "pipelined":
            y = _pipelined_exchange(y, axis, p, chunks)
        elif comm == "agas":
            y = _agas_exchange(y, axis, p)
        else:
            raise ValueError(f"comm backend {comm!r}; options {COMM_BACKENDS}")
        # transpose AFTER communication (paper §3.2): write-contiguous rows
        yt = (y[0].T, y[1].T)                                   # (mh_pad/p, n)
        z = execute(col_plan, yt)                               # column FFTs
        if keep_transposed:
            return z
        zt = (z[0].T, z[1].T)                                   # (n, mh_pad/p)
        return _a2a(zt, axis, split=0, concat=1)                # (n/p, mh_pad)

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=P(axis, None),
        out_specs=(P(None, axis) if keep_transposed else P(axis, None)),
    )(x)


def ifft2_slab(c: Complex, mesh: jax.sharding.Mesh, axis: str, m: int,
               planner: Optional[Planner] = None, comm: str = "collective",
               from_transposed: bool = False,
               permuted_cols: bool = False) -> jax.Array:
    """Inverse of :func:`fft2_slab` back to a real (N, M) array."""
    planner = planner or Planner(backends=("jnp",))
    n = c[0].shape[1] if from_transposed else c[0].shape[0]
    p = mesh.shape[axis]
    mh = m // 2 + 1
    mh_pad = padded_half(m, p)
    col_plan = planner.plan(n, kind="c2c", permuted=permuted_cols)
    row_plan = planner.plan(m, kind="c2r")

    def local(cr: jax.Array, ci: jax.Array) -> jax.Array:
        z = (cr, ci)
        if not from_transposed:                                 # (n/p, mh_pad)
            z = _a2a(z, axis, split=1, concat=0)                # (n, mh_pad/p)
            z = (z[0].T, z[1].T)                                # (mh_pad/p, n)
        if permuted_cols:
            zi = algo.ifft_from_permuted((z[0], z[1]),
                                         factors=col_plan.factors,
                                         karatsuba=col_plan.karatsuba)
        else:
            zi = algo.ifft((z[0], z[1]), factors=col_plan.factors or None,
                           karatsuba=col_plan.karatsuba)        # inverse cols
        zt = (zi[0].T, zi[1].T)                                 # (n, mh_pad/p)
        y = _a2a(zt, axis, split=0, concat=1)                   # (n/p, mh_pad)
        y = (y[0][:, :mh], y[1][:, :mh])                        # crop padding
        return execute(row_plan, y)                             # c2r rows

    in_spec = P(None, axis) if from_transposed else P(axis, None)
    return jax.shard_map(local, mesh=mesh, in_specs=(in_spec, in_spec),
                         out_specs=P(axis, None))(c[0], c[1])


# ---------------------------------------------------------------------------
# communication backends
# ---------------------------------------------------------------------------


def _pipelined_exchange(y: Complex, axis: str, p: int, chunks: int) -> Complex:
    """Chunked all_to_all pipeline (the LCI-parcelport analogue).

    Each device's DESTINATION column block [d*W, (d+1)*W) (W = mh_pad/p) is
    split into ``chunks`` sub-blocks; sub-block c of every destination is
    exchanged by its own all_to_all, so the concatenation of the received
    chunks reproduces the monolithic layout exactly.  XLA emits independent
    all-to-all-start/done pairs, so on hardware chunk c's transfer overlaps
    chunk c+1's residual compute; bytes on the wire are identical to the
    monolithic collective, but the exposed communication time shrinks.
    """
    rloc, mh_pad = y[0].shape
    w_dest = mh_pad // p
    chunks = max(1, min(chunks, w_dest))
    while w_dest % chunks:
        chunks -= 1
    wc = w_dest // chunks

    y3 = (y[0].reshape(rloc, p, w_dest), y[1].reshape(rloc, p, w_dest))
    outs = []
    for c in range(chunks):
        piece = (jax.lax.dynamic_slice_in_dim(y3[0], c * wc, wc, 2)
                 .reshape(rloc, p * wc),
                 jax.lax.dynamic_slice_in_dim(y3[1], c * wc, wc, 2)
                 .reshape(rloc, p * wc))
        outs.append(_a2a(piece, axis, split=1, concat=0))       # (n, wc)
    re = jnp.concatenate([o[0] for o in outs], axis=1)
    im = jnp.concatenate([o[1] for o in outs], axis=1)
    return re, im


def _agas_exchange(y: Complex, axis: str, p: int) -> Complex:
    """AGAS emulation: implicit addressing = replicate-then-slice.

    Every locality gathers the FULL matrix (p x the necessary bytes) and then
    resolves its slice through a global index — the redundant data movement
    the paper measures for the AGAS variant.
    """
    re = jax.lax.all_gather(y[0], axis, axis=0, tiled=True)     # (n, mh_pad)
    im = jax.lax.all_gather(y[1], axis, axis=0, tiled=True)
    i = jax.lax.axis_index(axis)
    w = re.shape[1] // p
    return (jax.lax.dynamic_slice_in_dim(re, i * w, w, 1),
            jax.lax.dynamic_slice_in_dim(im, i * w, w, 1))


# ---------------------------------------------------------------------------
# distribute / collect (the paper's `scatter` collective setup step)
# ---------------------------------------------------------------------------


def distribute(x: jax.Array, mesh: jax.sharding.Mesh, axis: str) -> jax.Array:
    """Scatter a host/global matrix into row slabs over ``axis`` (the paper's
    hpx scatter collective before the FFT)."""
    from jax.sharding import NamedSharding
    return jax.device_put(x, NamedSharding(mesh, P(axis, None)))


def collect(x: jax.Array) -> np.ndarray:
    """Gather slabs back to a single host array (paper: gather/concat)."""
    return np.asarray(jax.device_get(x))


# ---------------------------------------------------------------------------
# communication-aware planning (FFTW-style planning applied to the paper's
# parcelport choice: pick the comm backend from the roofline model)
# ---------------------------------------------------------------------------


def plan_comm(n: int, m: int, p: int, hw=None,
              overlap_capable: bool = True) -> str:
    """Choose the communication backend for an (n x m) slab FFT on p chips.

    Cost model (per device, per exchange):
      collective: wire = 2 * (p-1)/p * slab_bytes           (two all_to_alls)
      pipelined:  same wire, exposed time ~ 1/chunks, but adds one slab
                  read+write of HBM traffic for the chunk copies
      agas:       wire = 2 * (p-1) * slab_bytes              (never chosen)
    The monolithic collective wins when the exchange is small relative to
    compute (it fuses best); pipelining wins when exposed-comm would exceed
    ~20% of the local FFT compute time and overlap hardware exists.
    """
    from .plan import TPU_V5E
    hw = hw or TPU_V5E
    mh_pad = padded_half(m, p)
    slab_bytes = (n / p) * mh_pad * 8.0
    wire = 2.0 * (p - 1) / p * slab_bytes
    t_comm = wire / hw.link_bw
    # local compute: four-step matmul flops for rows + cols
    from .algo import default_factorization
    flops = 8.0 * (n / p) * mh_pad * (sum(default_factorization(m // 2))
                                      + sum(default_factorization(n)))
    t_comp = flops / hw.flops
    if overlap_capable and t_comm > 0.2 * t_comp:
        return "pipelined"
    return "collective"


# ---------------------------------------------------------------------------
# pencil-decomposed 3D c2c FFT (P3DFFT-style, 2D mesh)
# ---------------------------------------------------------------------------


def fft3_pencil(x: Complex, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                planner: Optional[Planner] = None) -> Complex:
    """3D c2c FFT of (X, Y, Z) sharded (P(ax0), P(ax1), None).

    Pencil decomposition: Z-FFT local; all_to_all over ``axes[1]`` swaps Y
    in; Y-FFT; all_to_all over ``axes[0]`` swaps X in; X-FFT.  Communication
    stays within row/column communicators — the P3DFFT advantage the paper
    cites over slab decomposition.  Output sharded (None, P(ax0), P(ax1))
    over (X -> local, Y, Z).
    """
    planner = planner or Planner(backends=("jnp",))
    nx, ny, nz = x[0].shape
    plan_z = planner.plan(nz, kind="c2c")
    plan_y = planner.plan(ny, kind="c2c")
    plan_x = planner.plan(nx, kind="c2c")
    ax0, ax1 = axes

    def local(cr: jax.Array, ci: jax.Array) -> Complex:
        z = execute(plan_z, (cr, ci))                           # FFT along Z
        # bring Y local: exchange Z<->Y within the ax1 communicator
        z = _a2a(z, ax1, split=2, concat=1)                     # (x/p0, y, z/p1)
        zt = (jnp.swapaxes(z[0], 1, 2), jnp.swapaxes(z[1], 1, 2))
        zy = execute(plan_y, zt)                                # FFT along Y
        zy = (jnp.swapaxes(zy[0], 1, 2), jnp.swapaxes(zy[1], 1, 2))
        # bring X local: exchange Y<->X within the ax0 communicator
        zy = _a2a(zy, ax0, split=1, concat=0)                   # (x, y/p0, z/p1)
        zx = (jnp.moveaxis(zy[0], 0, -1), jnp.moveaxis(zy[1], 0, -1))
        zz = execute(plan_x, zx)                                # FFT along X
        return jnp.moveaxis(zz[0], -1, 0), jnp.moveaxis(zz[1], -1, 0)

    return jax.shard_map(local, mesh=mesh,
                         in_specs=(P(ax0, ax1, None), P(ax0, ax1, None)),
                         out_specs=(P(None, ax0, ax1), P(None, ax0, ax1)))(x[0], x[1])
