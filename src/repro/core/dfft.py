"""Distributed multidimensional FFT on a device mesh (the paper's §3.2/§5.3).

Slab decomposition over one mesh axis, pencil decomposition over two.  All
data movement is EXPLICIT collectives inside ``shard_map`` — the paper's
central design decision ("relying on the implicit communication HPX allows
with AGAS does not make sense; instead we use the HPX equivalents of the MPI
collective operations").

All redistributions go through the swappable exchange layer in
:mod:`repro.core.comm` (``collective`` / ``pipelined`` / ``agas`` — see that
module for the cost characteristics and the ``plan_comm`` /
``plan_comm_pencil`` roofline planners).  Every entry point takes a ``comm``
spec: a backend name, a :class:`repro.core.comm.CommBackend` instance,
``"auto"`` (roofline-planned), ``"measure"`` (timed on the live mesh, FFTW
MEASURE applied to the parcelport choice, verdict cached in the planner's
unified wisdom store), or — for the pencil path — a per-mesh-axis
sequence/dict so the row and column communicators can use different
strategies (``"auto"``/``"measure"`` are valid per-axis entries too).

Algorithm (slab, 2D r2c, row-major N x M, P devices; paper's five steps):

  1. local r2c FFTs along contiguous rows          (N/P, Mh)
  2. COMMUNICATE: all_to_all column slabs          -> (N, Mh/P)  [rearrange
     = split into N_locs parts + concat, fused into the tiled collective]
  3. transpose AFTER communication (paper's choice) -> (Mh/P, N)
  4. local c2c FFTs along (now contiguous) columns
  5. COMMUNICATE back + rearrange to original layout (N/P, Mh)

The transform matches ``numpy.fft.rfft2`` zero-padded to the padded column
count; ``Mh`` is padded to a multiple of P for collective divisibility and
cropped by the caller-facing wrappers.

Pencil decomposition (P3DFFT-style, 2D mesh) has full parity with slab:
forward/inverse c2c (:func:`fft3_pencil` / :func:`ifft3_pencil`) and r2c/c2r
(:func:`rfft3_pencil` / :func:`irfft3_pencil`) with the same padded-half
cropping convention as the 2D path.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import algo
from .comm import (COMM_BACKENDS, CommBackend, CommSpec,
                   _normalize_axis_specs, get_backend, measure_comm_pencil,
                   measure_comm_slab, padded_half, plan_comm,
                   plan_comm_pencil, resolve_axis_backends)
from .compat import shard_map
from .plan import Plan, Planner, execute, execute_inverse

Complex = algo.Complex

__all__ = [
    "COMM_BACKENDS", "padded_half", "plan_comm", "plan_comm_pencil",
    "measure_comm_slab", "measure_comm_pencil",
    "fft2_slab", "ifft2_slab",
    "fft3_pencil", "ifft3_pencil", "rfft3_pencil", "irfft3_pencil",
    "distribute", "collect",
]


# ---------------------------------------------------------------------------
# local building blocks (run per-device inside shard_map)
# ---------------------------------------------------------------------------


def _local_rows_rfft(x: jax.Array, plan: Plan, mh_pad: int) -> Complex:
    """r2c FFT along the last axis + zero-pad to the collective-divisible
    width (works for any number of leading batch axes)."""
    re, im = execute(plan, x)
    pad = mh_pad - re.shape[-1]
    if pad:
        widths = ((0, 0),) * (re.ndim - 1) + ((0, pad),)
        re = jnp.pad(re, widths)
        im = jnp.pad(im, widths)
    return re, im


# ---------------------------------------------------------------------------
# slab-decomposed 2D r2c FFT
# ---------------------------------------------------------------------------


def fft2_slab(x: jax.Array, mesh: jax.sharding.Mesh, axis: str,
              planner: Optional[Planner] = None,
              comm: CommSpec = "collective", chunks: int = 4,
              keep_transposed: bool = False,
              permuted_cols: bool = False):
    """Distributed 2D r2c FFT.

    x: real (N, M), sharded (P(axis), None).  Returns (re, im) of shape
    (N, mh_pad) sharded the same way (crop to M//2+1 for the exact rfft2),
    or (mh_pad, N) sharded over rows if ``keep_transposed`` (saves the whole
    second communication step when the consumer accepts transposed layout —
    e.g. convolution pipelines that come straight back).

    ``comm`` selects the exchange backend (see :mod:`repro.core.comm`);
    ``"auto"`` plans it from the roofline model of ``planner``'s hardware,
    ``"measure"`` times every backend on the live mesh once and caches the
    verdict in the planner's wisdom store.

    ``permuted_cols`` skips the column FFT's digit transpose (output columns
    arrive in four-step permuted frequency order — valid for pointwise
    spectral consumers; pair with ``ifft2_slab(..., permuted_cols=True)``).
    One fewer memory pass per column transform.
    """
    planner = planner or Planner(backends=("jnp",))
    n, m = x.shape
    p = mesh.shape[axis]
    if comm == "auto":
        comm = plan_comm(n, m, p, hw=planner.hw)
    elif comm == "measure":
        comm = measure_comm_slab(n, m, mesh, axis, wisdom=planner.wisdom)
    backend = get_backend(comm, chunks=chunks)
    mh_pad = padded_half(m, p)
    row_plan = planner.plan(m, kind="r2c")
    col_plan = planner.plan(n, kind="c2c", permuted=permuted_cols)

    def local(xl: jax.Array) -> Tuple[jax.Array, jax.Array]:
        y = _local_rows_rfft(xl, row_plan, mh_pad)              # (n/p, mh_pad)
        y = backend.exchange(y, axis, split=1, concat=0, p=p)   # (n, mh_pad/p)
        # transpose AFTER communication (paper §3.2): write-contiguous rows
        yt = (y[0].T, y[1].T)                                   # (mh_pad/p, n)
        z = execute(col_plan, yt)                               # column FFTs
        if keep_transposed:
            return z
        zt = (z[0].T, z[1].T)                                   # (n, mh_pad/p)
        return backend.exchange(zt, axis, split=0, concat=1, p=p)

    return shard_map(
        local, mesh=mesh,
        in_specs=P(axis, None),
        out_specs=(P(None, axis) if keep_transposed else P(axis, None)),
    )(x)


def ifft2_slab(c: Complex, mesh: jax.sharding.Mesh, axis: str, m: int,
               planner: Optional[Planner] = None,
               comm: CommSpec = "collective", chunks: int = 4,
               from_transposed: bool = False,
               permuted_cols: bool = False) -> jax.Array:
    """Inverse of :func:`fft2_slab` back to a real (N, M) array."""
    planner = planner or Planner(backends=("jnp",))
    n = c[0].shape[1] if from_transposed else c[0].shape[0]
    p = mesh.shape[axis]
    if comm == "auto":
        comm = plan_comm(n, m, p, hw=planner.hw)
    elif comm == "measure":
        # the inverse retraces the forward exchanges, so it shares the
        # forward transform's wisdom key (and any cached verdict)
        comm = measure_comm_slab(n, m, mesh, axis, wisdom=planner.wisdom)
    backend = get_backend(comm, chunks=chunks)
    mh = m // 2 + 1
    col_plan = planner.plan(n, kind="c2c", permuted=permuted_cols)
    row_plan = planner.plan(m, kind="c2r")

    def local(cr: jax.Array, ci: jax.Array) -> jax.Array:
        z = (cr, ci)
        if not from_transposed:                                 # (n/p, mh_pad)
            z = backend.exchange(z, axis, split=1, concat=0, p=p)
            z = (z[0].T, z[1].T)                                # (mh_pad/p, n)
        zi = execute_inverse(col_plan, z)                       # inverse cols
        zt = (zi[0].T, zi[1].T)                                 # (n, mh_pad/p)
        y = backend.exchange(zt, axis, split=0, concat=1, p=p)  # (n/p, mh_pad)
        y = (y[0][:, :mh], y[1][:, :mh])                        # crop padding
        return execute(row_plan, y)                             # c2r rows

    in_spec = P(None, axis) if from_transposed else P(axis, None)
    return shard_map(local, mesh=mesh, in_specs=(in_spec, in_spec),
                     out_specs=P(axis, None))(c[0], c[1])


# ---------------------------------------------------------------------------
# distribute / collect (the paper's `scatter` collective setup step)
# ---------------------------------------------------------------------------


def distribute(x: jax.Array, mesh: jax.sharding.Mesh, axis: str) -> jax.Array:
    """Scatter a host/global matrix into row slabs over ``axis`` (the paper's
    hpx scatter collective before the FFT)."""
    from jax.sharding import NamedSharding
    return jax.device_put(x, NamedSharding(mesh, P(axis, None)))


def collect(x: jax.Array) -> np.ndarray:
    """Gather slabs back to a single host array (paper: gather/concat)."""
    return np.asarray(jax.device_get(x))


# ---------------------------------------------------------------------------
# pencil-decomposed 3D FFTs (P3DFFT-style, 2D mesh)
# ---------------------------------------------------------------------------
#
# Layout convention (forward direction), mesh axes (ax0, ax1) = (p0, p1):
#
#   input   (X/p0, Y/p1, Z)    Z-FFT local
#   xchg 1  over ax1 (row communicator):   split Z, concat Y
#           (X/p0, Y, Z/p1)    Y-FFT local
#   xchg 2  over ax0 (column communicator): split Y, concat X
#           (X,   Y/p0, Z/p1)  X-FFT local
#
# Communication stays within row/column communicators — the P3DFFT advantage
# the paper cites over slab decomposition.  The inverses retrace the same
# exchanges backwards, so each mesh axis keeps its chosen comm backend in
# both directions.


def _pencil_backends(comm, axes, chunks, planner, shape, mesh, kind):
    """Resolve the per-axis comm backends for a pencil transform.

    ``"auto"`` entries (whole-argument or per-axis) are planned from the
    roofline model; ``"measure"`` entries are timed on the live mesh, one
    measurement per row/column communicator, with verdicts cached in the
    planner's wisdom store (and a process-global memo, so retraces are
    free).  Mixed per-axis arguments only pay for the axes that ask.
    """
    specs = list(_normalize_axis_specs(comm, axes))
    special = [s for s in specs if isinstance(s, str)]
    if "auto" in special:
        p0, p1 = mesh.shape[axes[0]], mesh.shape[axes[1]]
        planned = plan_comm_pencil(shape, (p0, p1), hw=planner.hw, kind=kind)
        specs = [planned[i] if s == "auto" else s
                 for i, s in enumerate(specs)]
    if "measure" in special:
        measured = measure_comm_pencil(
            shape, mesh, axes, kind=kind, wisdom=planner.wisdom,
            which=tuple(s == "measure" for s in specs))
        specs = [measured[i] if s == "measure" else s
                 for i, s in enumerate(specs)]
    return resolve_axis_backends(tuple(specs), axes, chunks=chunks)


def fft3_pencil(x: Complex, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                planner: Optional[Planner] = None,
                comm: CommSpec = "collective", chunks: int = 4) -> Complex:
    """3D c2c FFT of (X, Y, Z) sharded (P(ax0), P(ax1), None).

    Output sharded (None, P(ax0), P(ax1)) over (X -> local, Y, Z).  ``comm``
    may be one spec for both communicators, a per-axis ``(ax0_spec,
    ax1_spec)`` pair, a dict keyed by mesh-axis name, or ``"auto"``.
    """
    planner = planner or Planner(backends=("jnp",))
    nx, ny, nz = x[0].shape
    b0, b1 = _pencil_backends(comm, axes, chunks, planner,
                              (nx, ny, nz), mesh, "c2c")
    plan_z = planner.plan(nz, kind="c2c")
    plan_y = planner.plan(ny, kind="c2c")
    plan_x = planner.plan(nx, kind="c2c")
    ax0, ax1 = axes
    p0, p1 = mesh.shape[ax0], mesh.shape[ax1]

    def local(cr: jax.Array, ci: jax.Array) -> Complex:
        z = execute(plan_z, (cr, ci))                           # FFT along Z
        # bring Y local: exchange Z<->Y within the ax1 communicator
        z = b1.exchange(z, ax1, split=2, concat=1, p=p1)        # (x/p0, y, z/p1)
        zt = (jnp.swapaxes(z[0], 1, 2), jnp.swapaxes(z[1], 1, 2))
        zy = execute(plan_y, zt)                                # FFT along Y
        zy = (jnp.swapaxes(zy[0], 1, 2), jnp.swapaxes(zy[1], 1, 2))
        # bring X local: exchange Y<->X within the ax0 communicator
        zy = b0.exchange(zy, ax0, split=1, concat=0, p=p0)      # (x, y/p0, z/p1)
        zx = (jnp.moveaxis(zy[0], 0, -1), jnp.moveaxis(zy[1], 0, -1))
        zz = execute(plan_x, zx)                                # FFT along X
        return jnp.moveaxis(zz[0], -1, 0), jnp.moveaxis(zz[1], -1, 0)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(ax0, ax1, None), P(ax0, ax1, None)),
                     out_specs=(P(None, ax0, ax1), P(None, ax0, ax1)))(x[0], x[1])


def ifft3_pencil(c: Complex, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                 planner: Optional[Planner] = None,
                 comm: CommSpec = "collective", chunks: int = 4) -> Complex:
    """Inverse of :func:`fft3_pencil`: (X, Y/p0, Z/p1) spectrum back to the
    (X/p0, Y/p1, Z) spatial layout.  Retraces the forward exchanges in
    reverse, per-axis comm backends as in the forward direction."""
    planner = planner or Planner(backends=("jnp",))
    nx, ny, nz = c[0].shape                                     # global shape
    ax0, ax1 = axes
    p0, p1 = mesh.shape[ax0], mesh.shape[ax1]
    b0, b1 = _pencil_backends(comm, axes, chunks, planner,
                              (nx, ny, nz), mesh, "c2c")
    plan_z = planner.plan(nz, kind="c2c")
    plan_y = planner.plan(ny, kind="c2c")
    plan_x = planner.plan(nx, kind="c2c")

    def local(cr: jax.Array, ci: jax.Array) -> Complex:
        z = (cr, ci)                                            # (x, y/p0, z/p1)
        zx = (jnp.moveaxis(z[0], 0, -1), jnp.moveaxis(z[1], 0, -1))
        zx = execute_inverse(plan_x, zx)                        # inverse X
        z = (jnp.moveaxis(zx[0], -1, 0), jnp.moveaxis(zx[1], -1, 0))
        z = b0.exchange(z, ax0, split=0, concat=1, p=p0)        # (x/p0, y, z/p1)
        zt = (jnp.swapaxes(z[0], 1, 2), jnp.swapaxes(z[1], 1, 2))
        zy = execute_inverse(plan_y, zt)                        # inverse Y
        z = (jnp.swapaxes(zy[0], 1, 2), jnp.swapaxes(zy[1], 1, 2))
        z = b1.exchange(z, ax1, split=1, concat=2, p=p1)        # (x/p0, y/p1, z)
        return execute_inverse(plan_z, z)                       # inverse Z

    return shard_map(local, mesh=mesh,
                     in_specs=(P(None, ax0, ax1), P(None, ax0, ax1)),
                     out_specs=(P(ax0, ax1, None), P(ax0, ax1, None)))(c[0], c[1])


def rfft3_pencil(x: jax.Array, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                 planner: Optional[Planner] = None,
                 comm: CommSpec = "collective", chunks: int = 4) -> Complex:
    """3D r2c FFT of a real (X, Y, Z) array sharded (P(ax0), P(ax1), None).

    The contiguous Z axis gets the r2c transform; its half spectrum is
    zero-padded to ``padded_half(Z, p1)`` for collective divisibility, the
    same convention as the 2D slab path.  Output: (re, im) of global shape
    (X, Y, zh_pad) sharded (None, P(ax0), P(ax1)) — crop the last axis to
    Z//2+1 for the exact ``numpy.fft.rfftn``.
    """
    planner = planner or Planner(backends=("jnp",))
    nx, ny, nz = x.shape
    ax0, ax1 = axes
    p0, p1 = mesh.shape[ax0], mesh.shape[ax1]
    b0, b1 = _pencil_backends(comm, axes, chunks, planner,
                              (nx, ny, nz), mesh, "r2c")
    zh_pad = padded_half(nz, p1)
    plan_z = planner.plan(nz, kind="r2c")
    plan_y = planner.plan(ny, kind="c2c")
    plan_x = planner.plan(nx, kind="c2c")

    def local(xl: jax.Array) -> Complex:
        z = _local_rows_rfft(xl, plan_z, zh_pad)                # (x/p0, y/p1, zh_pad)
        z = b1.exchange(z, ax1, split=2, concat=1, p=p1)        # (x/p0, y, zh_pad/p1)
        zt = (jnp.swapaxes(z[0], 1, 2), jnp.swapaxes(z[1], 1, 2))
        zy = execute(plan_y, zt)                                # FFT along Y
        zy = (jnp.swapaxes(zy[0], 1, 2), jnp.swapaxes(zy[1], 1, 2))
        zy = b0.exchange(zy, ax0, split=1, concat=0, p=p0)      # (x, y/p0, zh_pad/p1)
        zx = (jnp.moveaxis(zy[0], 0, -1), jnp.moveaxis(zy[1], 0, -1))
        zz = execute(plan_x, zx)                                # FFT along X
        return jnp.moveaxis(zz[0], -1, 0), jnp.moveaxis(zz[1], -1, 0)

    return shard_map(local, mesh=mesh,
                     in_specs=P(ax0, ax1, None),
                     out_specs=(P(None, ax0, ax1), P(None, ax0, ax1)))(x)


def irfft3_pencil(c: Complex, mesh: jax.sharding.Mesh, axes: Tuple[str, str],
                  nz: int, planner: Optional[Planner] = None,
                  comm: CommSpec = "collective",
                  chunks: int = 4) -> jax.Array:
    """Inverse of :func:`rfft3_pencil` back to a real (X, Y, Z) array.

    Takes the *uncropped* padded spectrum (global (X, Y, zh_pad), sharded
    (None, P(ax0), P(ax1))) plus the original Z length ``nz``, mirroring
    :func:`ifft2_slab`'s padded-half cropping."""
    planner = planner or Planner(backends=("jnp",))
    nx, ny = c[0].shape[0], c[0].shape[1]                       # global shape
    ax0, ax1 = axes
    p0, p1 = mesh.shape[ax0], mesh.shape[ax1]
    b0, b1 = _pencil_backends(comm, axes, chunks, planner,
                              (nx, ny, nz), mesh, "c2r")
    zh = nz // 2 + 1
    plan_zr = planner.plan(nz, kind="c2r")
    plan_y = planner.plan(ny, kind="c2c")
    plan_x = planner.plan(nx, kind="c2c")

    def local(cr: jax.Array, ci: jax.Array) -> jax.Array:
        z = (cr, ci)                                            # (x, y/p0, zh_pad/p1)
        zx = (jnp.moveaxis(z[0], 0, -1), jnp.moveaxis(z[1], 0, -1))
        zx = execute_inverse(plan_x, zx)                        # inverse X
        z = (jnp.moveaxis(zx[0], -1, 0), jnp.moveaxis(zx[1], -1, 0))
        z = b0.exchange(z, ax0, split=0, concat=1, p=p0)        # (x/p0, y, zh_pad/p1)
        zt = (jnp.swapaxes(z[0], 1, 2), jnp.swapaxes(z[1], 1, 2))
        zy = execute_inverse(plan_y, zt)                        # inverse Y
        z = (jnp.swapaxes(zy[0], 1, 2), jnp.swapaxes(zy[1], 1, 2))
        z = b1.exchange(z, ax1, split=1, concat=2, p=p1)        # (x/p0, y/p1, zh_pad)
        z = (z[0][..., :zh], z[1][..., :zh])                    # crop padding
        return execute(plan_zr, z)                              # c2r along Z

    return shard_map(local, mesh=mesh,
                     in_specs=(P(None, ax0, ax1), P(None, ax0, ax1)),
                     out_specs=P(ax0, ax1, None))(c[0], c[1])
