"""FFT-based long convolution — the paper's distributed FFT as an LM mixer.

Hyena/S4-style token mixing is a length-L causal convolution, computed as
  y = ifft( fft(pad(u)) * fft(pad(k)) )[:L]
which is exactly the workload the paper studies: batched 1D FFTs plus a
global data redistribution when the sequence is sharded across devices.

Two beyond-paper TPU optimizations are first-class here:

* **Transpose elision** (permuted frequency order): the pointwise product
  commutes with the four-step digit permutation, so both the forward digit
  transpose and the inverse's un-permute are skipped (`permuted=True` plans).
  For the *distributed* path this removes the global transpose entirely —
  only the two all_to_all exchanges of the paper's algorithm remain, fwd and
  bwd (4 total), versus 6 exchanges for an order-preserving pipeline.

* **Overlap-ready chunked exchanges** (`comm="pipelined"`), via the shared
  exchange layer in :mod:`repro.core.comm` — the same swappable backends the
  slab/pencil paths in :mod:`repro.core.dfft` use.

The distributed 1D FFT views the length-L signal as an (N1, N2) matrix
(row-major), sharded over n1 — the paper's own 2D framing of the problem:

  stage A: all_to_all -> columns local; DFT along n1; twiddle T[k1, n2]
  stage B: all_to_all -> rows local;   DFT along n2
  output C[k1, k2] row-sharded, permuted order.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import algo
from .comm import (CommBackend, CommSpec, get_backend, measure_comm_conv,
                   plan_comm_conv)
from .compat import batched_spec, shard_map
from .plan import Planner

Complex = algo.Complex


def next_fft_len(n: int) -> int:
    """Smallest power of two >= n (all assigned seq lens are powers of two)."""
    m = 1
    while m < n:
        m *= 2
    return m


def factor_split(n: int, p: int) -> Optional[Tuple[int, int]]:
    """Factor a 1D transform length for the distributed factor-split FFT:
    ``n = n1 * n2`` with both factors divisible by ``p`` (every exchange is
    a tiled all_to_all over ``p`` participants) and as close to ``sqrt(n)``
    as the divisors allow.  Returns ``None`` when no such split exists
    (``n`` not a multiple of ``p**2``, or a factor would be an
    unfactorizable prime) — the caller falls back to a local transform.

    Shared by :func:`fft_conv_seq_sharded` and the ``factor1d``
    decomposition of :func:`repro.core.api.plan_nd`.
    """
    if p < 1 or n % (p * p):
        return None
    r = n // (p * p)
    best = None
    for a in range(1, int(np.sqrt(r)) + 1):
        if r % a == 0:
            best = a                    # largest divisor <= sqrt(r)
    n1, n2 = p * best, p * (r // best)
    try:                                # both stages must be plannable
        algo.default_factorization(n1)
        algo.default_factorization(n2)
    except ValueError:
        return None
    return n1, n2


# ---------------------------------------------------------------------------
# implicit filter parameterization (Hyena-lite): tiny param count at any L
# ---------------------------------------------------------------------------


def filter_basis(length: int, rank: int, dtype=jnp.float32) -> jax.Array:
    """(rank, length) damped-oscillator basis, generated in-graph via iota so
    a 500k-length filter costs no parameter memory."""
    t = jax.lax.iota(jnp.float32, length)[None, :] / max(length, 1)
    r = jax.lax.iota(jnp.float32, rank)[:, None]
    decay = jnp.exp(-jnp.exp(0.5 * r) * t)
    phase = jnp.cos(2.0 * np.pi * (r + 1.0) * t)
    return (decay * phase).astype(dtype)


def materialize_filter(weights: jax.Array, length: int) -> jax.Array:
    """weights (D, rank) -> causal filters (D, length)."""
    basis = filter_basis(length, weights.shape[-1], weights.dtype)
    return weights @ basis


# ---------------------------------------------------------------------------
# single-device FFT convolution
# ---------------------------------------------------------------------------


def fft_conv(u: jax.Array, k: jax.Array, planner: Optional[Planner] = None,
             permuted: bool = True) -> jax.Array:
    """Causal convolution via FFT.

    u: (B, L, D) real activations; k: (D, L) real causal filters.
    Returns (B, L, D).  Uses c2c on the real signal (imag = 0) so the
    permuted-order transpose elision applies end to end.
    """
    b, slen, d = u.shape
    nf = next_fft_len(2 * slen)
    planner = planner or Planner(backends=("jnp",))
    plan = planner.plan(nf, kind="c2c", permuted=permuted)

    ut = jnp.moveaxis(u, 1, 2).astype(jnp.float32)              # (B, D, L)
    up = jnp.pad(ut, ((0, 0), (0, 0), (0, nf - slen)))
    kp = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, nf - slen)))

    from .plan import execute, execute_inverse
    uf = execute(plan, (up, jnp.zeros_like(up)))
    kf = execute(plan, (kp, jnp.zeros_like(kp)))
    prod = algo.cmul(uf, kf)
    y = execute_inverse(plan, prod)[0]                          # real part
    return jnp.moveaxis(y[..., :slen], 2, 1).astype(u.dtype)


# ---------------------------------------------------------------------------
# sequence-sharded distributed FFT convolution (shard_map)
# ---------------------------------------------------------------------------


def _dist_fft_permuted(x: Complex, axis: str, p: int, n1: int, n2: int,
                       sign: int, planner: Planner,
                       backend: Optional[CommBackend] = None) -> Complex:
    """Distributed c2c FFT along axis 1 of local (B, Lloc, D) blocks.

    Global length N = n1 * n2, row-major (n1, n2), sharded over n1.
    Returns C[k1, k2] (permuted order), k1-sharded: local (B, Lloc, D).
    """
    from .plan import execute
    backend = backend or get_backend("collective")
    bsz, lloc, d = x[0].shape
    n1loc = n1 // p
    assert lloc == n1loc * n2, (lloc, n1, n2, p)
    plan1 = planner.plan(n1, kind="c2c")
    plan2 = planner.plan(n2, kind="c2c")

    def r4(a):  # (B, n1loc, n2, D) view
        return a.reshape(bsz, n1loc, n2, d)

    a = (r4(x[0]), r4(x[1]))
    # stage A: columns local
    a = backend.exchange(a, axis, split=2, concat=1, p=p)       # (B, n1, n2/p, D)
    at = (jnp.moveaxis(a[0], 1, -1), jnp.moveaxis(a[1], 1, -1))  # n1 last
    bt = execute(plan1, at) if sign < 0 else _inv_exec(plan1, at)
    bm = (jnp.moveaxis(bt[0], -1, 1), jnp.moveaxis(bt[1], -1, 1))
    # twiddle T[k1, n2-block], sliced to this device's n2 columns
    tw = algo.twiddle_factors(n1, n2, sign)
    me = jax.lax.axis_index(axis)
    w = n2 // p
    twr = jax.lax.dynamic_slice_in_dim(tw[0], me * w, w, 1)     # (n1, n2/p)
    twi = jax.lax.dynamic_slice_in_dim(tw[1], me * w, w, 1)
    btw = algo.cmul(bm, (twr[None, :, :, None], twi[None, :, :, None]))
    # stage B: rows local
    c = backend.exchange(btw, axis, split=1, concat=2, p=p)     # (B, n1/p, n2, D)
    ct = (jnp.moveaxis(c[0], 2, -1), jnp.moveaxis(c[1], 2, -1))  # n2 last
    dt = execute(plan2, ct) if sign < 0 else _inv_exec(plan2, ct)
    dm = (jnp.moveaxis(dt[0], -1, 2), jnp.moveaxis(dt[1], -1, 2))
    return dm[0].reshape(bsz, lloc, d), dm[1].reshape(bsz, lloc, d)


def _inv_exec(plan, x):
    """Unnormalized inverse (sign=+1) transform with the plan's recipe."""
    return algo.fft(x, sign=+1, factors=plan.factors or None,
                    karatsuba=plan.karatsuba)


def _dist_ifft_permuted(x: Complex, axis: str, p: int, n1: int, n2: int,
                        planner: Planner,
                        backend: Optional[CommBackend] = None) -> Complex:
    """Inverse of :func:`_dist_fft_permuted` (consumes permuted order)."""
    from .plan import execute
    backend = backend or get_backend("collective")
    bsz, lloc, d = x[0].shape
    n1loc = n1 // p
    n = n1 * n2
    plan1 = planner.plan(n1, kind="c2c")
    plan2 = planner.plan(n2, kind="c2c")

    c = (x[0].reshape(bsz, n1loc, n2, d), x[1].reshape(bsz, n1loc, n2, d))
    # inverse DFT along k2 (rows are local)
    ct = (jnp.moveaxis(c[0], 2, -1), jnp.moveaxis(c[1], 2, -1))
    bt = _inv_exec(plan2, ct)
    b = (jnp.moveaxis(bt[0], -1, 2), jnp.moveaxis(bt[1], -1, 2))
    # conjugate twiddle T[k1-block, n2]
    tw = algo.twiddle_factors(n1, n2, +1)
    me = jax.lax.axis_index(axis)
    twr = jax.lax.dynamic_slice_in_dim(tw[0], me * n1loc, n1loc, 0)
    twi = jax.lax.dynamic_slice_in_dim(tw[1], me * n1loc, n1loc, 0)
    b = algo.cmul(b, (twr[None, :, :, None], twi[None, :, :, None]))
    # all_to_all -> columns local; inverse DFT along k1
    a = backend.exchange(b, axis, split=2, concat=1, p=p)       # (B, n1, n2/p, D)
    at = (jnp.moveaxis(a[0], 1, -1), jnp.moveaxis(a[1], 1, -1))
    ot = _inv_exec(plan1, at)
    o = (jnp.moveaxis(ot[0], -1, 1), jnp.moveaxis(ot[1], -1, 1))
    # back to row-sharded layout
    o = backend.exchange(o, axis, split=1, concat=2, p=p)       # (B, n1/p, n2, D)
    scale = 1.0 / n
    return (o[0].reshape(bsz, lloc, d) * scale,
            o[1].reshape(bsz, lloc, d) * scale)


def fft_conv_seq_sharded(u: jax.Array, k: jax.Array,
                         mesh: jax.sharding.Mesh, axis: str,
                         planner: Optional[Planner] = None,
                         comm: CommSpec = "collective",
                         chunks: int = 4) -> jax.Array:
    """Causal FFT convolution with the sequence sharded over ``axis``.

    u: (B, L, D) with L sharded; k: (D, L_full) replicated filters.
    The paper's distributed algorithm, transposed-order end to end.
    ``comm`` picks the exchange backend (see :mod:`repro.core.comm`);
    ``"auto"`` plans it from the roofline model, ``"measure"`` times the
    backends on the live mesh (verdict cached in the planner's wisdom).
    """
    planner = planner or Planner(backends=("jnp",))
    b, slen, d = u.shape
    p = mesh.shape[axis]
    nf = next_fft_len(2 * slen)
    # both factors near sqrt(nf), each divisible by p (stage-A AND stage-B
    # exchanges are tiled all_to_alls) — the same split the factor1d
    # decomposition of plan_nd uses
    split = factor_split(nf, p)
    assert split is not None, f"sequence too short for mesh: nf={nf}, p={p}"
    n1, n2 = split
    if comm == "auto":
        comm = plan_comm_conv(b, d, n1, n2, p, hw=planner.hw)
    elif comm == "measure":
        comm = measure_comm_conv(b, d, n1, n2, mesh, axis,
                                 wisdom=planner.wisdom)
    backend = get_backend(comm, chunks=chunks)

    # global zero-padding to the FFT length (outside shard_map: the tail
    # zeros live on the trailing devices of the sequence axis)
    up = jnp.pad(u.astype(jnp.float32), ((0, 0), (0, nf - slen), (0, 0)))
    kp = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, nf - slen)))

    def local(ul: jax.Array, kl: jax.Array) -> jax.Array:
        klt = kl.T[None]                                        # (1, nf/p, D)
        uf = _dist_fft_permuted((ul, jnp.zeros_like(ul)), axis, p, n1, n2,
                                -1, planner, backend)
        kf = _dist_fft_permuted((klt, jnp.zeros_like(klt)), axis, p, n1, n2,
                                -1, planner, backend)
        prod = algo.cmul(uf, kf)
        return _dist_ifft_permuted(prod, axis, p, n1, n2, planner, backend)[0]

    # the (B, L, D) activations and (D, L) filters share the batched-spec
    # convention of the dfft executors: one leading replicated batch dim
    # prepended to the sharded-sequence spec
    y = shard_map(
        local, mesh=mesh,
        in_specs=(batched_spec(P(axis, None), 1), batched_spec(P(axis), 1)),
        out_specs=batched_spec(P(axis, None), 1),
    )(up, kp)
    return y[:, :slen, :].astype(u.dtype)
