"""Four-step (Bailey) matmul FFT — the TPU-native 1D FFT substrate.

The paper's FFTW backend computes batched 1D FFTs with SIMD butterfly codelets.
On TPU the 128x128 MXU makes *dense DFT matmuls* the right primitive, so we use
the four-step factorization  N = N1*N2:

    A[n1, n2]   = x[n1*N2 + n2]                       (row-major reshape)
    B[k1, n2]   = sum_n1 A[n1, n2] * W_N1^{n1 k1}      (DFT along axis 0)
    B'[k1, n2]  = B[k1, n2] * W_N^{n2 k1}              (twiddle)
    C[k1, k2]   = sum_n2 B'[k1, n2] * W_N2^{n2 k2}     (DFT along axis 1)
    X[k2*N1+k1] = C[k1, k2]                            (digit transpose)

Sub-DFTs recurse until the factor is <= the planner's ``max_base`` and is
executed as a dense matmul.  Complex numbers are carried as (re, im) pairs of
real arrays (the MXU has no complex type); a complex contraction costs 4 real
matmuls, or 3 with the Karatsuba trick.

``permuted=True`` skips the final digit transpose (decimated frequency order).
``ifft_from_permuted`` consumes that order directly, which lets FFT
convolutions skip both transposes (FlashFFTConv-style) — pointwise products
commute with a fixed permutation.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Complex = Tuple[jax.Array, jax.Array]  # (re, im)

# ---------------------------------------------------------------------------
# complex-pair helpers
# ---------------------------------------------------------------------------


def to_pair(z) -> Complex:
    """jnp/np complex array -> (re, im) pair."""
    z = jnp.asarray(z)
    return jnp.real(z), jnp.imag(z)


def to_complex(c: Complex) -> jax.Array:
    return jax.lax.complex(jnp.asarray(c[0], jnp.float32), jnp.asarray(c[1], jnp.float32))


def cmul(a: Complex, b: Complex) -> Complex:
    return a[0] * b[0] - a[1] * b[1], a[0] * b[1] + a[1] * b[0]


def cadd(a: Complex, b: Complex) -> Complex:
    return a[0] + b[0], a[1] + b[1]


def conj(a: Complex) -> Complex:
    return a[0], -a[1]


def cscale(a: Complex, s) -> Complex:
    return a[0] * s, a[1] * s


# ---------------------------------------------------------------------------
# DFT / twiddle tables (host-side numpy; closed over as constants)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _dft_matrix_np(n: int, sign: int) -> Tuple[np.ndarray, np.ndarray]:
    """W[j, k] = exp(sign * 2*pi*i * j*k / n); float64 then cast to f32."""
    jk = np.outer(np.arange(n), np.arange(n)).astype(np.float64)
    ang = sign * 2.0 * np.pi * jk / n
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


@functools.lru_cache(maxsize=None)
def _twiddle_np(n1: int, n2: int, sign: int) -> Tuple[np.ndarray, np.ndarray]:
    """T[k1, n2] = exp(sign * 2*pi*i * k1*n2 / (n1*n2))."""
    jk = np.outer(np.arange(n1), np.arange(n2)).astype(np.float64)
    ang = sign * 2.0 * np.pi * jk / (n1 * n2)
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def dft_matrix(n: int, sign: int = -1) -> Complex:
    re, im = _dft_matrix_np(n, sign)
    return jnp.asarray(re), jnp.asarray(im)


def twiddle_factors(n1: int, n2: int, sign: int = -1) -> Complex:
    re, im = _twiddle_np(n1, n2, sign)
    return jnp.asarray(re), jnp.asarray(im)


# ---------------------------------------------------------------------------
# complex matmul (..., n) x (n, k) -> (..., k), 4-matmul or Karatsuba 3-matmul
# ---------------------------------------------------------------------------


def _mm(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def complex_matmul(a: Complex, w: Complex, karatsuba: bool = False) -> Complex:
    """(ar + i*ai) @ (wr + i*wi), contracting a's last dim with w's first."""
    ar, ai = a
    wr, wi = w
    if karatsuba:
        # 3 real matmuls: p1 = ar@wr, p2 = ai@wi, p3 = (ar+ai)@(wr+wi)
        p1 = _mm(ar, wr)
        p2 = _mm(ai, wi)
        p3 = _mm(ar + ai, wr + wi)
        return p1 - p2, p3 - p1 - p2
    return _mm(ar, wr) - _mm(ai, wi), _mm(ar, wi) + _mm(ai, wr)


# ---------------------------------------------------------------------------
# factorization planning helper (the Planner in plan.py builds on this)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def default_factorization(n: int, max_base: int = 128) -> Tuple[int, ...]:
    """Split n into factors each <= max_base, minimizing (#factors, sum).

    The four-step cost is ~ N * sum(factors) MACs, so the sum is the flop
    count and fewer factors means fewer twiddle/transpose passes.  Balanced
    splits win: 256 -> (16, 16), 16384 -> (128, 128), 2**19 -> (128, 64, 64).
    """
    if n <= max_base:
        return (n,)
    best = None

    def key(fs):
        return (len(fs), sum(fs), -min(fs))

    for f in range(2, max_base + 1):
        if n % f == 0:
            try:
                rest = default_factorization(n // f, max_base)
            except ValueError:
                continue
            cand = tuple(sorted((f,) + rest, reverse=True))
            if best is None or key(cand) < key(best):
                best = cand
    if best is None:
        raise ValueError(f"cannot factor {n} with base <= {max_base}")
    return best


# ---------------------------------------------------------------------------
# core c2c FFT along the last axis
# ---------------------------------------------------------------------------


def _fft_base(x: Complex, sign: int, karatsuba: bool) -> Complex:
    """Dense DFT matmul along the last axis."""
    n = x[0].shape[-1]
    return complex_matmul(x, dft_matrix(n, sign), karatsuba)


def _fft_factors(x: Complex, factors: Sequence[int], sign: int,
                 karatsuba: bool, permuted: bool) -> Complex:
    """Four-step FFT along the last axis with the given factorization."""
    n = x[0].shape[-1]
    if len(factors) == 1:
        assert factors[0] == n, (factors, n)
        return _fft_base(x, sign, karatsuba)
    n1 = factors[0]
    n2 = n // n1
    batch = x[0].shape[:-1]
    a = (x[0].reshape(batch + (n1, n2)), x[1].reshape(batch + (n1, n2)))

    # step 1: DFT_n1 along axis -2. Contract with W1 via last-axis matmul on the
    # transposed view (..., n2, n1) — this is the "columns" FFT of the paper.
    at = (jnp.swapaxes(a[0], -1, -2), jnp.swapaxes(a[1], -1, -2))
    bt = complex_matmul(at, dft_matrix(n1, sign), karatsuba)  # (..., n2, k1)
    b = (jnp.swapaxes(bt[0], -1, -2), jnp.swapaxes(bt[1], -1, -2))  # (..., k1, n2)

    # step 2: twiddle T[k1, n2]
    tw = twiddle_factors(n1, n2, sign)
    b = cmul(b, tw)

    # step 3: DFT_n2 along the last axis (recurse on remaining factors)
    c = _fft_factors(b, tuple(factors[1:]), sign, karatsuba, permuted=False) \
        if len(factors) > 2 else _fft_base(b, sign, karatsuba)
    # note: recursing with permuted=False keeps inner ordering canonical; only
    # the *top level* may skip its digit transpose.

    if permuted:
        return c[0].reshape(batch + (n,)), c[1].reshape(batch + (n,))
    # step 4: digit transpose  X[k2*n1 + k1] = C[k1, k2]
    ct = (jnp.swapaxes(c[0], -1, -2), jnp.swapaxes(c[1], -1, -2))
    return ct[0].reshape(batch + (n,)), ct[1].reshape(batch + (n,))


def fft(x: Complex, *, sign: int = -1, factors: Sequence[int] | None = None,
        max_base: int = 128, karatsuba: bool = False,
        permuted: bool = False) -> Complex:
    """c2c FFT along the last axis of an (re, im) pair."""
    n = x[0].shape[-1]
    if factors is None:
        factors = default_factorization(n, max_base)
    return _fft_factors(x, tuple(factors), sign, karatsuba, permuted)


def ifft(x: Complex, *, factors: Sequence[int] | None = None,
         max_base: int = 128, karatsuba: bool = False) -> Complex:
    n = x[0].shape[-1]
    y = fft(x, sign=+1, factors=factors, max_base=max_base, karatsuba=karatsuba)
    return cscale(y, 1.0 / n)


def ifft_from_permuted(x: Complex, *, factors: Sequence[int] | None = None,
                       max_base: int = 128, karatsuba: bool = False) -> Complex:
    """Inverse FFT consuming the ``permuted=True`` forward output.

    Forward (permuted) stopped at C[k1, k2].  The inverse of the *ordered*
    transform composed with the missing digit-transpose cancels to: inverse
    DFT along k2, conjugate twiddle, inverse DFT along k1, flatten — no
    transposes at all.  Only valid for two-factor plans (the planner enforces
    this when it selects permuted mode).
    """
    n = x[0].shape[-1]
    if factors is None:
        factors = default_factorization(n, max_base)
    if len(factors) != 2:
        raise ValueError("permuted mode requires a two-factor plan")
    n1, n2 = factors
    batch = x[0].shape[:-1]
    c = (x[0].reshape(batch + (n1, n2)), x[1].reshape(batch + (n1, n2)))
    # inverse DFT along k2 (last axis)
    b = complex_matmul(c, dft_matrix(n2, +1), karatsuba)
    # conjugate twiddle
    b = cmul(b, twiddle_factors(n1, n2, +1))
    # inverse DFT along k1 (axis -2)
    bt = (jnp.swapaxes(b[0], -1, -2), jnp.swapaxes(b[1], -1, -2))
    at = complex_matmul(bt, dft_matrix(n1, +1), karatsuba)
    a = (jnp.swapaxes(at[0], -1, -2), jnp.swapaxes(at[1], -1, -2))
    out = (a[0].reshape(batch + (n,)), a[1].reshape(batch + (n,)))
    return cscale(out, 1.0 / n)


# ---------------------------------------------------------------------------
# real-to-complex (the paper's transform kind) via pack-as-complex
# ---------------------------------------------------------------------------


def _half_twiddle(n: int, sign: int) -> Complex:
    m = n // 2
    k = np.arange(m + 1).astype(np.float64)
    ang = sign * 2.0 * np.pi * k / n
    return jnp.asarray(np.cos(ang).astype(np.float32)), jnp.asarray(np.sin(ang).astype(np.float32))


def rfft(x: jax.Array, **kw) -> Complex:
    """r2c FFT along the last axis. len must be even; output length n//2 + 1.

    Packs even/odd samples into a complex signal of length n/2, runs one c2c
    FFT, and unpacks with conjugate symmetry — halving MXU work exactly like
    FFTW's real codelets halve flops.
    """
    n = x.shape[-1]
    assert n % 2 == 0, "rfft requires even length"
    m = n // 2
    z = (x[..., 0::2], x[..., 1::2])
    zf = fft(z, sign=-1, **kw)  # (..., m)
    # Z[(-k) mod m], k = 0..m  (index m wraps to 0)
    idx = (-np.arange(m + 1)) % m
    zr = (zf[0][..., idx], zf[1][..., idx])
    zk = (jnp.concatenate([zf[0], zf[0][..., :1]], -1),
          jnp.concatenate([zf[1], zf[1][..., :1]], -1))
    xe = cscale(cadd(zk, conj(zr)), 0.5)                       # even spectrum
    xo_t = cadd(zk, cscale(conj(zr), -1.0))                    # Z - conj(Zrev)
    xo = (0.5 * xo_t[1], -0.5 * xo_t[0])                       # /(2i)
    w = _half_twiddle(n, -1)
    return cadd(xe, cmul(w, xo))


def irfft(x: Complex, **kw) -> jax.Array:
    """c2r inverse FFT; input (..., n//2+1), output real (..., n)."""
    m = x[0].shape[-1] - 1
    n = 2 * m
    w = _half_twiddle(n, +1)
    xr = (x[0][..., ::-1], x[1][..., ::-1])                    # X[m-k]
    xe = cscale(cadd(x, conj(xr)), 0.5)
    xo_f = cscale(cadd(x, cscale(conj(xr), -1.0)), 0.5)
    xo = cmul(w, xo_f)                                          # undo half twiddle
    # Z[k] = Xe[k] + i*Xo[k], k = 0..m-1
    z = (xe[0][..., :m] - xo[1][..., :m], xe[1][..., :m] + xo[0][..., :m])
    zi = ifft(z, **kw)
    out = jnp.stack([zi[0], zi[1]], axis=-1)                    # interleave
    return out.reshape(out.shape[:-2] + (n,))


# ---------------------------------------------------------------------------
# multidimensional transforms (the paper's 2D algorithm, axis-by-axis)
# ---------------------------------------------------------------------------


def fft2(x: Complex, **kw) -> Complex:
    """2D c2c FFT over the last two axes: rows then columns via transpose."""
    y = fft(x, **kw)                                            # along axis -1
    yt = (jnp.swapaxes(y[0], -1, -2), jnp.swapaxes(y[1], -1, -2))
    zt = fft(yt, **kw)                                          # along old axis -2
    return jnp.swapaxes(zt[0], -1, -2), jnp.swapaxes(zt[1], -1, -2)


def ifft2(x: Complex, **kw) -> Complex:
    y = ifft(x, **kw)
    yt = (jnp.swapaxes(y[0], -1, -2), jnp.swapaxes(y[1], -1, -2))
    zt = ifft(yt, **kw)
    return jnp.swapaxes(zt[0], -1, -2), jnp.swapaxes(zt[1], -1, -2)


def rfft2(x: jax.Array, **kw) -> Complex:
    """2D r2c: r2c along the contiguous rows, then c2c along columns."""
    y = rfft(x, **kw)                                           # (..., N, M//2+1)
    yt = (jnp.swapaxes(y[0], -1, -2), jnp.swapaxes(y[1], -1, -2))
    zt = fft(yt, **kw)
    return jnp.swapaxes(zt[0], -1, -2), jnp.swapaxes(zt[1], -1, -2)


def fftn(x: Complex, ndim: int, **kw) -> Complex:
    """n-D c2c FFT over the last ``ndim`` axes."""
    y = x
    for ax in range(ndim):
        axis = -1 - ax
        yt = (jnp.moveaxis(y[0], axis, -1), jnp.moveaxis(y[1], axis, -1))
        zt = fft(yt, **kw)
        y = (jnp.moveaxis(zt[0], -1, axis), jnp.moveaxis(zt[1], -1, axis))
    return y
