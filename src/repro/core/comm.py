"""Communication backends for distributed-FFT redistributions (paper §5.3).

The paper's headline distributed result is that the *exchange* dominates
distributed FFT time, and that a faster exchange layer (the LCI parcelport,
up to 5x) is worth swapping in wholesale.  This module makes the exchange a
first-class, swappable subsystem: one :class:`CommBackend` implementation
per strategy, shared by the slab (:func:`repro.core.dfft.fft2_slab`), pencil
(:func:`repro.core.dfft.fft3_pencil`) and sequence-sharded convolution
(:mod:`repro.core.fftconv`) paths instead of per-path inlined collectives.

Backends (paper §5.3, Fig. 6):

* ``collective`` — one monolithic ``jax.lax.all_to_all`` per redistribution
  (HPX collectives over the MPI parcelport; XLA's stock schedule).
* ``pipelined`` — the redistribution is split into chunks; chunk c's
  all_to_all is issued while chunk c+1's FFT computes, a software pipeline
  that hides link latency behind MXU work.  Same bytes on the wire, less
  *exposed* time — the TPU-native analogue of the LCI parcelport speedup.
  Spell ``"pipelined:8"`` to override the chunk count inline.
* ``agas`` — all-gather-then-slice: every locality materializes the full
  array and resolves its block through a global index, emulating the
  redundant data movement of implicit AGAS addressing.  Implemented to
  *measure* the overhead the paper plots (Fig. 1, dark blue), not to be
  used.

An exchange is described positionally, matching ``jax.lax.all_to_all``
tiled semantics: "split axis ``split`` into the ``p`` participants, send
block d to participant d, concatenate received blocks along ``concat``".
One implementation therefore serves the 2D slab layout, the 3D pencil
row/column communicators, and the 4D convolution layout.

Communication *planning* also lives here, in both of the paper's modes:

* ESTIMATE — :func:`plan_comm` (1D slab decomposition), :func:`plan_comm_pencil`
  (2D-mesh pencil decomposition, one choice per row/column communicator),
  :func:`plan_comm_conv` (sequence-sharded convolution) and
  :func:`plan_comm_gather` (compressed all-reduce) pick a backend from the
  roofline model — FFTW-style ESTIMATE planning applied to the paper's
  parcelport choice.
* MEASURE — the :func:`measure_comm` family compiles and times every
  backend (collective / pipelined with a chunk-count sweep / agas) on the
  LIVE mesh for the actual exchange shape and keeps the fastest, exactly
  FFTW's MEASURE dynamic programming applied to the §5.3 parcelport swing.
  Verdicts are recorded in the unified wisdom store
  (:class:`repro.core.wisdom.WisdomStore`) under ``comm/*`` keys, next to
  the planner's ``plan/*`` entries, and memoized in-process so a given
  ``(shape, mesh_shape, kind, axis)`` exchange is timed once — never once
  per jit trace.  Spell ``comm="measure"`` at any transform entry point.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from . import algo
from .wisdom import WisdomStore

Complex = algo.Complex

COMM_BACKENDS = ("collective", "pipelined", "agas")


def pad_to(n: int, p: int) -> int:
    """``n`` rounded up to a multiple of ``p`` (collective divisibility)."""
    return -(-n // p) * p


def padded_half(m: int, p: int) -> int:
    """Column count after r2c (m//2+1) padded up to a multiple of p."""
    return pad_to(m // 2 + 1, p)


# ---------------------------------------------------------------------------
# pair-valued collective primitives (the only place raw collectives appear)
# ---------------------------------------------------------------------------


def a2a_pair(c: Complex, axis_name: str, split: int, concat: int) -> Complex:
    """Tiled all_to_all of an (re, im) pair."""
    f = functools.partial(jax.lax.all_to_all, axis_name=axis_name,
                          split_axis=split, concat_axis=concat, tiled=True)
    return f(c[0]), f(c[1])


def all_gather_pair(c: Complex, axis_name: str, axis: int = 0,
                    tiled: bool = False) -> Complex:
    """all_gather of a pair of same-layout arrays (spectrum halves, or any
    payload+metadata pair such as int8 gradients + scales)."""
    f = functools.partial(jax.lax.all_gather, axis_name=axis_name,
                          axis=axis, tiled=tiled)
    return f(c[0]), f(c[1])


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


class CommBackend:
    """One redistribution strategy for pair-valued sharded exchanges."""

    name: str = "abstract"

    def exchange(self, c: Complex, axis_name: str, *, split: int,
                 concat: int, p: int) -> Complex:
        """Redistribute: split ``split`` over the ``p`` participants of
        ``axis_name``, concatenate received blocks along ``concat``."""
        raise NotImplementedError

    def gather(self, c: Complex, axis_name: str) -> Complex:
        """Stacked all_gather of a pair (leading participant axis added) —
        the collective :func:`repro.optim.compress.compressed_psum` rides.
        Both pair members must share their leading dimension."""
        return all_gather_pair(c, axis_name)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}()"


class CollectiveBackend(CommBackend):
    """Monolithic all_to_all (MPI-parcelport analogue)."""

    name = "collective"

    def exchange(self, c, axis_name, *, split, concat, p):
        return a2a_pair(c, axis_name, split, concat)


class PipelinedBackend(CommBackend):
    """Chunked all_to_all software pipeline (LCI-parcelport analogue).

    Each participant's DESTINATION block of width W = size(split)/p is cut
    into ``chunks`` sub-blocks; sub-block c of every destination is
    exchanged by its own all_to_all, so the concatenation of received chunks
    along ``split`` reproduces the monolithic layout exactly.  XLA emits
    independent all-to-all-start/done pairs, so on hardware chunk c's
    transfer overlaps chunk c+1's residual compute; bytes on the wire are
    identical to the monolithic collective, but the exposed communication
    time shrinks.
    """

    name = "pipelined"

    def __init__(self, chunks: int = 4):
        self.chunks = chunks

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"PipelinedBackend(chunks={self.chunks})"

    def exchange(self, c, axis_name, *, split, concat, p):
        shape = c[0].shape
        w = shape[split] // p
        chunks = max(1, min(self.chunks, w))
        while w % chunks:
            chunks -= 1
        if chunks == 1:
            return a2a_pair(c, axis_name, split, concat)
        wc = w // chunks
        grouped = shape[:split] + (p, w) + shape[split + 1:]
        flat = shape[:split] + (p * wc,) + shape[split + 1:]
        g = (c[0].reshape(grouped), c[1].reshape(grouped))
        outs = []
        for k in range(chunks):
            piece = tuple(
                jax.lax.dynamic_slice_in_dim(a, k * wc, wc, split + 1)
                .reshape(flat) for a in g)
            outs.append(a2a_pair(piece, axis_name, split, concat))
        return (jnp.concatenate([o[0] for o in outs], axis=split),
                jnp.concatenate([o[1] for o in outs], axis=split))

    def gather(self, c, axis_name):
        """Chunked stacked all_gather: the leading (shared) dimension is cut
        into ``chunks`` pieces, each gathered by its own collective so
        transfers overlap; received chunks concatenate along axis 1 (the
        pre-gather leading dim, shifted by the new participant axis)."""
        n = c[0].shape[0]
        chunks = max(1, min(self.chunks, n))
        while n % chunks:
            chunks -= 1
        if chunks == 1:
            return all_gather_pair(c, axis_name)
        w = n // chunks
        outs = [all_gather_pair(
            tuple(jax.lax.dynamic_slice_in_dim(a, k * w, w, 0) for a in c),
            axis_name) for k in range(chunks)]
        return (jnp.concatenate([o[0] for o in outs], axis=1),
                jnp.concatenate([o[1] for o in outs], axis=1))


class AgasBackend(CommBackend):
    """AGAS emulation: implicit addressing = replicate-then-slice.

    Every locality gathers the FULL array (p x the necessary bytes) along
    the concat direction and then resolves its block through a global index
    — the redundant data movement the paper measures for the AGAS variant.
    """

    name = "agas"

    def exchange(self, c, axis_name, *, split, concat, p):
        re, im = all_gather_pair(c, axis_name, axis=concat, tiled=True)
        i = jax.lax.axis_index(axis_name)
        w = re.shape[split] // p
        return (jax.lax.dynamic_slice_in_dim(re, i * w, w, split),
                jax.lax.dynamic_slice_in_dim(im, i * w, w, split))


# ---------------------------------------------------------------------------
# resolution: strings (and per-axis collections of strings) -> backends
# ---------------------------------------------------------------------------

CommSpec = Union[str, CommBackend]


def get_backend(spec: CommSpec, chunks: int = 4) -> CommBackend:
    """Resolve a backend spec: a :class:`CommBackend` instance, or one of
    ``"collective"`` / ``"pipelined"`` (optionally ``"pipelined:<chunks>"``)
    / ``"agas"``."""
    if isinstance(spec, CommBackend):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"comm spec must be str or CommBackend, got {spec!r}")
    name, _, arg = spec.partition(":")
    if name == "collective":
        return CollectiveBackend()
    if name == "pipelined":
        return PipelinedBackend(int(arg) if arg else chunks)
    if name == "agas":
        return AgasBackend()
    if name in ("auto", "measure"):
        raise ValueError(
            f"comm={spec!r} is resolved at the transform entry points "
            "(fft2_slab, fft3_pencil, ...), which know the mesh and shape; "
            "pass it there, or call plan_comm*/measure_comm* yourself")
    raise ValueError(f"comm backend {spec!r}; options {COMM_BACKENDS}")


def _normalize_axis_specs(comm, axes: Sequence[str]) -> Tuple[CommSpec, ...]:
    """Expand a per-axis comm argument to one raw spec per mesh axis.

    ``comm`` may be a single spec (applied to every axis), a sequence with
    one spec per axis (ordered as ``axes``), or a dict keyed by mesh-axis
    name (missing axes default to ``"collective"``).  Specs are NOT resolved
    to backends here, so ``"auto"``/``"measure"`` survive for the caller.
    """
    if isinstance(comm, dict):
        unknown = set(comm) - set(axes)
        if unknown:
            raise ValueError(
                f"per-axis comm has unknown mesh axes {sorted(unknown)}; "
                f"valid axes: {tuple(axes)}")
        return tuple(comm.get(a, "collective") for a in axes)
    if isinstance(comm, (list, tuple)):
        if len(comm) != len(axes):
            raise ValueError(
                f"per-axis comm needs {len(axes)} entries for {axes}, "
                f"got {len(comm)}")
        return tuple(comm)
    return tuple(comm for _ in axes)


def resolve_axis_backends(comm, axes: Sequence[str],
                          chunks: int = 4) -> Tuple[CommBackend, ...]:
    """Per-mesh-axis backend resolution for multi-axis (pencil) paths
    (see :func:`_normalize_axis_specs` for the accepted shapes)."""
    return tuple(get_backend(s, chunks)
                 for s in _normalize_axis_specs(comm, axes))


# ---------------------------------------------------------------------------
# communication-aware planning, ESTIMATE mode (FFTW-style planning applied
# to the paper's parcelport choice: pick the comm backend from the roofline)
# ---------------------------------------------------------------------------


def _roofline_choice(wire_bytes: float, flops: float, hw,
                     overlap_capable: bool = True) -> str:
    """The shared decision rule: the monolithic collective wins when the
    exchange is small relative to the compute it could hide behind (it
    fuses best); pipelining wins when exposed-comm would exceed ~20% of
    that compute time and overlap hardware exists."""
    t_comm = wire_bytes / hw.link_bw
    t_comp = flops / hw.flops
    if overlap_capable and t_comm > 0.2 * t_comp:
        return "pipelined"
    return "collective"


def plan_comm(n: int, m: int, p: int, hw=None,
              overlap_capable: bool = True) -> str:
    """Choose the communication backend for an (n x m) slab FFT on p chips.

    Cost model (per device, per exchange):
      collective: wire = 2 * (p-1)/p * slab_bytes           (two all_to_alls)
      pipelined:  same wire, exposed time ~ 1/chunks, but adds one slab
                  read+write of HBM traffic for the chunk copies
      agas:       wire = 2 * (p-1) * slab_bytes              (never chosen)
    The monolithic collective wins when the exchange is small relative to
    compute (it fuses best); pipelining wins when exposed-comm would exceed
    ~20% of the local FFT compute time and overlap hardware exists.
    """
    from .plan import TPU_V5E
    hw = hw or TPU_V5E
    mh_pad = padded_half(m, p)
    slab_bytes = (n / p) * mh_pad * 8.0
    wire = 2.0 * (p - 1) / p * slab_bytes
    # local compute: four-step matmul flops for rows + cols
    flops = 8.0 * (n / p) * mh_pad * (
        sum(algo.default_factorization(m // 2))
        + sum(algo.default_factorization(n)))
    return _roofline_choice(wire, flops, hw, overlap_capable)


def plan_comm_slab_nd(shape: Sequence[int], p: int, hw=None,
                      kind: str = "c2c",
                      overlap_capable: bool = True) -> str:
    """:func:`plan_comm` generalized to an N-D slab decomposition: the first
    transform axis is sharded over ``p`` devices, the last axis (its r2c half
    spectrum, for real kinds) is split in the exchange, every other axis is
    local.  The 2D r2c case coincides with :func:`plan_comm`."""
    from .plan import TPU_V5E
    hw = hw or TPU_V5E
    if p <= 1:
        return "collective"
    last = padded_half(shape[-1], p) if kind in ("r2c", "c2r") \
        else pad_to(shape[-1], p)
    elems = float(np.prod([pad_to(shape[0], p), *shape[1:-1]])) * last
    wire = 2.0 * (p - 1) / p * (elems / p) * 8.0
    flops = 8.0 * (elems / p) * sum(fac_sum(n) for n in shape)
    return _roofline_choice(wire, flops, hw, overlap_capable)


def fac_sum(n: int) -> float:
    """Four-step MAC count per element for a length-``n`` stage, falling
    back to the direct DFT for lengths the factorizer cannot split (the
    shared cost kernel of the slab and N-D decomposition rooflines)."""
    try:
        return float(sum(algo.default_factorization(n)))
    except ValueError:
        return float(n)


def plan_comm_pencil_nd(shape: Sequence[int], mesh_shape: Sequence[int],
                        hw=None, overlap_capable: bool = True,
                        kind: str = "c2c") -> Tuple[str, ...]:
    """Choose per-mesh-axis comm backends for a k-axis pencil FFT of an
    N-D transform (``k = len(mesh_shape)`` sharded leading axes, one
    exchange per adjacent pair of the chain).

    Unlike the 1D slab model, pencil exchanges run inside row/column(/...)
    communicators: exchange ``j`` stays within the ``p_j``-sized
    communicator of mesh axis ``j`` and overlaps the FFT stage along
    transform axis ``j``.  Each communicator is planned independently
    against the stage it can hide behind:

      wire_j = (p_j - 1)/p_j * pencil_bytes
      t_comp = four-step matmul flops of stage j / hw.flops

    Returns one backend spec per mesh axis, in decomposition order (the
    order :func:`repro.core.dfft.execute_pencil` consumes).
    """
    from .plan import TPU_V5E
    hw = hw or TPU_V5E
    mesh_shape = tuple(int(p) for p in mesh_shape)
    nlast_eff = padded_half(shape[-1], mesh_shape[-1]) \
        if kind in ("r2c", "c2r") else shape[-1]
    # the local pencil: an (re, im) f32 pair, constant across every exchange
    devices = float(np.prod(mesh_shape))
    elems = float(np.prod(shape[:-1])) * nlast_eff / devices
    pencil_bytes = elems * 8.0

    def choose(p: int, n_axis: int) -> str:
        if p <= 1:
            return "collective"
        wire = (p - 1) / p * pencil_bytes
        flops = 8.0 * elems * sum(algo.default_factorization(n_axis))
        return _roofline_choice(wire, flops, hw, overlap_capable)

    # mesh axis j's exchange feeds the FFT stage along transform axis j
    return tuple(choose(p, shape[j]) for j, p in enumerate(mesh_shape))


def plan_comm_pencil(shape: Tuple[int, int, int],
                     mesh_shape: Tuple[int, int], hw=None,
                     overlap_capable: bool = True,
                     kind: str = "c2c") -> Tuple[str, str]:
    """The 3D/2-mesh-axis case of :func:`plan_comm_pencil_nd` (P3DFFT
    layout: the Z<->Y exchange inside the p1-sized row communicator, the
    Y<->X exchange inside the p0-sized column communicator)."""
    s0, s1 = plan_comm_pencil_nd(shape, mesh_shape, hw=hw,
                                 overlap_capable=overlap_capable, kind=kind)
    return s0, s1


def plan_comm_factor1d(n: int, n1: int, n2: int, p: int, hw=None,
                       overlap_capable: bool = True) -> str:
    """Choose the exchange backend for the distributed 1D factor-split FFT
    (:func:`repro.core.dfft.execute_factor1d`): the length-``n`` signal is
    viewed as an (n1, n2) matrix sharded over n1; each of the three
    exchanges (stage A, stage B, un-permute) moves the local
    ``(n1/p, n2)`` pair while a DFT stage computes."""
    from .plan import TPU_V5E
    hw = hw or TPU_V5E
    if p <= 1:
        return "collective"
    elems = float(n) / p
    wire = (p - 1) / p * elems * 8.0
    flops = 8.0 * elems * (fac_sum(n1) + fac_sum(n2))
    return _roofline_choice(wire, flops, hw, overlap_capable)


def plan_comm_conv(bsz: int, d: int, n1: int, n2: int, p: int, hw=None,
                   overlap_capable: bool = True) -> str:
    """Choose the exchange backend for the sequence-sharded FFT convolution
    (:func:`repro.core.fftconv.fft_conv_seq_sharded`): the length-``n1*n2``
    signal is viewed as an (n1, n2) matrix sharded over n1, and each of the
    algorithm's all_to_alls moves the local (bsz, n1/p, n2, d) block while
    a DFT stage computes."""
    from .plan import TPU_V5E
    hw = hw or TPU_V5E
    if p <= 1:
        return "collective"
    elems = bsz * (n1 / p) * n2 * d
    wire = (p - 1) / p * elems * 8.0
    flops = 8.0 * elems * (sum(algo.default_factorization(n1))
                           + sum(algo.default_factorization(n2)))
    return _roofline_choice(wire, flops, hw, overlap_capable)


def plan_comm_gather(n_elems: int, p: int, block: int = 256, hw=None,
                     overlap_capable: bool = True) -> str:
    """Choose the gather backend for the int8 compressed all-reduce
    (:func:`repro.optim.compress.compressed_psum`): every participant
    receives p x the quantized payload (int8 values + bf16 per-block
    scales) and the dequantize-sum is the only compute to hide behind."""
    from .plan import TPU_V5E
    hw = hw or TPU_V5E
    if p <= 1:
        return "collective"
    wire = p * (n_elems + (n_elems / block) * 2.0)
    flops = 2.0 * p * n_elems
    return _roofline_choice(wire, flops, hw, overlap_capable)


# ---------------------------------------------------------------------------
# communication-aware planning, MEASURE mode (FFTW MEASURE applied to the
# parcelport choice: time every backend on the live mesh, keep the fastest)
# ---------------------------------------------------------------------------

DEFAULT_CHUNK_SWEEP = (2, 4, 8)

#: timing probes actually executed (one per candidate backend); tests and
#: benchmarks snapshot this to prove wisdom/memo hits re-measure nothing.
MEASURE_STATS = {"timed": 0}

# process-global verdict memo, keyed like the wisdom store.  Transform entry
# points construct a fresh default Planner per call, so without this memo a
# jit retrace (or a planner-less second call) would re-run the measurement;
# with it, each (shape, mesh_shape, kind, axis) exchange is timed exactly
# once per process no matter how many traces consume the verdict.
_MEASURE_MEMO: Dict[str, dict] = {}


def forget_measurements() -> None:
    """Drop the in-process comm measurement memo (wisdom files persist)."""
    _MEASURE_MEMO.clear()


def _effective_chunks(chunks: int, w: int) -> int:
    """The chunk count :class:`PipelinedBackend` will actually use for a
    destination-block width of ``w``."""
    c = max(1, min(chunks, w))
    while w % c:
        c -= 1
    return c


def _time_callable(fn, args, reps: int) -> float:
    """Compile + warmup, then wall-time ``reps`` executions (median-free
    mean, like ``Planner._measure``).  Returns +inf on any failure so a
    broken candidate loses rather than crashes the sweep."""
    try:
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
    except Exception:
        return float("inf")
    MEASURE_STATS["timed"] += 1
    return dt


def _time_exchange(backend: CommBackend, mesh, axis_name: str,
                   local_shape: Sequence[int], split: int, concat: int,
                   p: int, reps: int) -> float:
    """Time one redistribution with ``backend`` on the live mesh.

    The probe reproduces the transform-local layout exactly: a global
    (re, im) f32 pair whose ``concat`` dimension is sharded over
    ``axis_name`` (every device holds ``local_shape``), redistributed to
    ``split``-sharded — the same collective the transform will emit.
    """
    from .compat import shard_map
    ndim = len(local_shape)
    global_shape = list(local_shape)
    global_shape[concat] *= p
    spec_in = [None] * ndim
    spec_in[concat] = axis_name
    spec_out = [None] * ndim
    spec_out[split] = axis_name
    pin, pout = PartitionSpec(*spec_in), PartitionSpec(*spec_out)
    rng = np.random.default_rng(0)
    probe = tuple(
        jax.device_put(rng.standard_normal(global_shape).astype(np.float32),
                       NamedSharding(mesh, pin)) for _ in range(2))

    def local(a, b):
        return backend.exchange((a, b), axis_name, split=split,
                                concat=concat, p=p)

    fn = jax.jit(shard_map(local, mesh=mesh, in_specs=(pin, pin),
                           out_specs=(pout, pout)))
    return _time_callable(fn, probe, reps)


def _time_gather(backend: CommBackend, mesh, axis_name: str, nb: int,
                 block: int, p: int, reps: int) -> float:
    """Time one compressed-payload gather (int8 values + bf16 scales) plus
    the dequantize-sum it must hide behind — the collective
    :func:`repro.optim.compress.compressed_psum` issues."""
    from .compat import shard_map
    rng = np.random.default_rng(0)
    q = jax.device_put(
        rng.integers(-127, 128, (p * nb, block)).astype(np.int8),
        NamedSharding(mesh, PartitionSpec(axis_name, None)))
    s = jax.device_put(
        rng.standard_normal((p * nb, 1)).astype(jnp.bfloat16),
        NamedSharding(mesh, PartitionSpec(axis_name, None)))

    def local(ql, sl):
        qg, sg = backend.gather((ql, sl), axis_name)
        return jnp.sum(qg.astype(jnp.float32) * sg.astype(jnp.float32),
                       axis=0)

    fn = jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(PartitionSpec(axis_name, None),) * 2,
        out_specs=PartitionSpec(axis_name, None)))
    return _time_callable(fn, (q, s), reps)


def measure_comm(mesh, axis_name: str, local_shape: Sequence[int], *,
                 split: int, concat: int,
                 chunk_candidates: Sequence[int] = DEFAULT_CHUNK_SWEEP,
                 reps: int = 3) -> Tuple[str, Dict[str, float]]:
    """FFTW MEASURE for one exchange: compile and time every backend — the
    monolithic collective, the pipelined exchange at each distinct feasible
    chunk count, and the agas gather emulation — on the LIVE mesh at the
    actual local shape, and return ``(fastest_spec, {spec: seconds})``.

    This is the raw, uncached timer; the keyed ``measure_comm_*`` wrappers
    add wisdom/memo consultation.  A 1-participant communicator returns
    ``("collective", {})`` without timing anything.
    """
    p = mesh.shape[axis_name]
    if p <= 1:
        return "collective", {}
    specs = _candidate_specs(local_shape[split] // p, chunk_candidates,
                             base=("collective", "agas"))
    return _run_sweep(specs, lambda spec: _time_exchange(
        get_backend(spec), mesh, axis_name, tuple(local_shape), split,
        concat, p, reps))


def _candidate_specs(width: int, chunk_candidates: Sequence[int],
                     base: Sequence[str]) -> Sequence[str]:
    """The sweep's candidate list: ``base`` plus one pipelined spec per
    DISTINCT effective chunk count (what :class:`PipelinedBackend` would
    actually use at this destination-block ``width``)."""
    specs = list(base)
    for c in sorted(set(int(c) for c in chunk_candidates)):
        ce = _effective_chunks(c, width)
        spec = f"pipelined:{ce}"
        if ce > 1 and spec not in specs:
            specs.append(spec)
    return specs


def _run_sweep(specs: Sequence[str], timer) -> Tuple[str, Dict[str, float]]:
    """Time every candidate and keep the fastest; failed candidates (inf)
    lose, and an all-failed sweep falls back to the collective."""
    timings = {spec: timer(spec) for spec in specs}
    finite = {k: v for k, v in timings.items() if v != float("inf")}
    if not finite:
        return "collective", timings
    return min(finite, key=finite.get), timings


def _measured_verdict(key: str, wisdom: Optional[WisdomStore], thunk) -> str:
    """Measurement cache: consult the wisdom store, then the process memo;
    run ``thunk`` (the actual timing sweep) only on a double miss, and
    record the verdict in both."""
    if wisdom is not None:
        hit = wisdom.get(key)
        if hit is not None:
            _MEASURE_MEMO.setdefault(key, hit)
            return hit["backend"]
    rec = _MEASURE_MEMO.get(key)
    if rec is None:
        best, timings = thunk()
        rec = {"backend": best,
               "seconds": timings.get(best, 0.0),
               "candidates": {k: (v if v != float("inf") else None)
                              for k, v in timings.items()}}
        _MEASURE_MEMO[key] = rec
    if wisdom is not None and key not in wisdom:
        wisdom.put(key, rec)
    return rec["backend"]


def measure_comm_slab(n: int, m: int, mesh, axis: str, kind: str = "r2c",
                      wisdom: Optional[WisdomStore] = None,
                      chunk_candidates: Sequence[int] = DEFAULT_CHUNK_SWEEP,
                      reps: int = 3) -> str:
    """Measured backend choice for the (n x m) slab FFT's exchanges.

    Times the first redistribution (split padded columns over the ``axis``
    communicator, concat rows); the return exchange moves the same bytes
    through the same communicator transposed, so one verdict serves both
    directions — and the inverse transform.
    """
    return measure_comm_slab_nd((n, m), mesh, axis, kind=kind, wisdom=wisdom,
                                chunk_candidates=chunk_candidates, reps=reps)


def measure_comm_slab_nd(shape: Sequence[int], mesh, axis: str,
                         kind: str = "r2c",
                         wisdom: Optional[WisdomStore] = None,
                         chunk_candidates: Sequence[int] = DEFAULT_CHUNK_SWEEP,
                         reps: int = 3) -> str:
    """:func:`measure_comm_slab` generalized to an N-D slab decomposition
    (first axis sharded, last axis split in the exchange, middles local).
    The 2D case shares its wisdom key with :func:`measure_comm_slab`."""
    p = mesh.shape[axis]
    if p <= 1:
        return "collective"
    last = padded_half(shape[-1], p) if kind in ("r2c", "c2r") \
        else pad_to(shape[-1], p)
    kind_key = "r2c" if kind in ("r2c", "c2r") else kind
    key = f"comm/slab/{'x'.join(str(s) for s in shape)}/p{p}/{kind_key}"
    local_shape = (pad_to(shape[0], p) // p, *shape[1:-1], last)
    return _measured_verdict(key, wisdom, lambda: measure_comm(
        mesh, axis, local_shape, split=len(local_shape) - 1, concat=0,
        chunk_candidates=chunk_candidates, reps=reps))


def measure_comm_pencil_nd(shape: Sequence[int], mesh,
                           axes: Sequence[str], kind: str = "c2c",
                           wisdom: Optional[WisdomStore] = None,
                           chunk_candidates: Sequence[int]
                           = DEFAULT_CHUNK_SWEEP,
                           reps: int = 3,
                           which: Optional[Sequence[bool]] = None):
    """Measured per-mesh-axis backend choice for a k-axis pencil FFT.

    Each communicator's exchange is measured independently at its true
    local shape in the execution chain (exchange ``j`` runs inside the
    ``axes[j]`` communicator, immediately before the FFT stage along
    transform axis ``j``).  Returns one spec per mesh axis, entries
    ``None`` where ``which`` masks them off (so per-axis ``comm``
    arguments can mix ``"measure"`` with explicit specs without paying
    for both).  The 3D/2-axis keys coincide with the historical
    :func:`measure_comm_pencil` keys.
    """
    d, k = len(shape), len(axes)
    ps = tuple(int(mesh.shape[a]) for a in axes)
    which = tuple(which) if which is not None else (True,) * k
    # c2r retraces r2c's exchanges with byte-identical probes, so the
    # inverse shares the forward's key (and any cached verdict) — same
    # convention as measure_comm_slab
    kind_key = "r2c" if kind in ("r2c", "c2r") else kind
    base = (f"comm/pencil/{'x'.join(str(s) for s in shape)}/"
            f"mesh{'x'.join(str(p) for p in ps)}/{kind_key}")
    # padded axis sizes in the chain — taken from NdPlan itself (ONE
    # definition of the pencil padding invariant), via a throwaway plan
    from .api import NdPlan
    padded = list(NdPlan(tuple(shape), kind_key, "pencil",
                         tuple(axes), ps).padded_spectrum_shape)

    def local_shape(j: int) -> Tuple[int, ...]:
        """Local (re, im) block just before exchange j in the forward
        chain: axes 0..j input-sharded, the donor axis full, axes past the
        donor already exchanged onto their final communicator."""
        out = []
        donor = j + 1 if j < k - 1 else d - 1
        for i in range(d):
            if i <= j:
                out.append(padded[i] // ps[i])
            elif i == donor:
                out.append(padded[i])
            elif i < k:
                out.append(padded[i] // ps[i - 1])
            elif i == d - 1:
                out.append(padded[i] // ps[k - 1])
            else:
                out.append(padded[i])
        return tuple(out)

    specs = [None] * k
    for j in range(k - 1, -1, -1):          # execution order of the chain
        if not which[j]:
            continue
        if ps[j] <= 1:
            specs[j] = "collective"
            continue
        donor = j + 1 if j < k - 1 else d - 1
        specs[j] = _measured_verdict(
            f"{base}/ax{j}", wisdom,
            lambda j=j, donor=donor: measure_comm(
                mesh, axes[j], local_shape(j), split=donor, concat=j,
                chunk_candidates=chunk_candidates, reps=reps))
    return tuple(specs)


def measure_comm_pencil(shape: Tuple[int, int, int], mesh,
                        axes: Sequence[str], kind: str = "c2c",
                        wisdom: Optional[WisdomStore] = None,
                        chunk_candidates: Sequence[int] = DEFAULT_CHUNK_SWEEP,
                        reps: int = 3,
                        which: Tuple[bool, bool] = (True, True)):
    """The 3D/2-mesh-axis case of :func:`measure_comm_pencil_nd` (kept for
    the historical call sites; same wisdom keys)."""
    s0, s1 = measure_comm_pencil_nd(
        tuple(shape), mesh, tuple(axes), kind=kind, wisdom=wisdom,
        chunk_candidates=chunk_candidates, reps=reps, which=which)
    return s0, s1


def measure_comm_factor1d(n: int, factors: Tuple[int, int], mesh, axis: str,
                          wisdom: Optional[WisdomStore] = None,
                          chunk_candidates: Sequence[int]
                          = DEFAULT_CHUNK_SWEEP,
                          reps: int = 3) -> str:
    """Measured backend choice for the distributed 1D factor-split FFT:
    times the stage-A exchange of the local (n1/p, n2) block (all three of
    the algorithm's exchanges move the same bytes through the same
    communicator)."""
    p = mesh.shape[axis]
    if p <= 1:
        return "collective"
    n1, n2 = factors
    key = f"comm/factor1d/{n}/{n1}x{n2}/p{p}"
    return _measured_verdict(key, wisdom, lambda: measure_comm(
        mesh, axis, (n1 // p, n2), split=1, concat=0,
        chunk_candidates=chunk_candidates, reps=reps))


def measure_comm_conv(bsz: int, d: int, n1: int, n2: int, mesh, axis: str,
                      wisdom: Optional[WisdomStore] = None,
                      chunk_candidates: Sequence[int] = DEFAULT_CHUNK_SWEEP,
                      reps: int = 3) -> str:
    """Measured backend choice for the sequence-sharded FFT convolution:
    times the stage-A exchange of the local (bsz, n1/p, n2, d) block (all
    four of the algorithm's exchanges move the same bytes)."""
    p = mesh.shape[axis]
    if p <= 1:
        return "collective"
    key = f"comm/conv/b{bsz}d{d}/{n1}x{n2}/p{p}"
    return _measured_verdict(key, wisdom, lambda: measure_comm(
        mesh, axis, (bsz, n1 // p, n2, d), split=2, concat=1,
        chunk_candidates=chunk_candidates, reps=reps))


def measure_comm_gather(mesh, axis_name: str, n_elems: int,
                        block: int = 256,
                        wisdom: Optional[WisdomStore] = None,
                        chunk_candidates: Sequence[int] = DEFAULT_CHUNK_SWEEP,
                        reps: int = 3) -> str:
    """Measured gather choice for the int8 compressed all-reduce over an
    ``n_elems``-element payload (agas is skipped: its gather IS the
    monolithic collective)."""
    p = mesh.shape[axis_name]
    if p <= 1:
        return "collective"
    nb = -(-n_elems // block)
    key = f"comm/gather/{n_elems}/b{block}/p{p}"
    return _measured_verdict(key, wisdom, lambda: _run_sweep(
        _candidate_specs(nb, chunk_candidates, base=("collective",)),
        lambda spec: _time_gather(get_backend(spec), mesh, axis_name,
                                  nb, block, p, reps)))
