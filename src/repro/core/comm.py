"""Communication backends for distributed-FFT redistributions (paper §5.3).

The paper's headline distributed result is that the *exchange* dominates
distributed FFT time, and that a faster exchange layer (the LCI parcelport,
up to 5x) is worth swapping in wholesale.  This module makes the exchange a
first-class, swappable subsystem: one :class:`CommBackend` implementation
per strategy, shared by the slab (:func:`repro.core.dfft.fft2_slab`), pencil
(:func:`repro.core.dfft.fft3_pencil`) and sequence-sharded convolution
(:mod:`repro.core.fftconv`) paths instead of per-path inlined collectives.

Backends (paper §5.3, Fig. 6):

* ``collective`` — one monolithic ``jax.lax.all_to_all`` per redistribution
  (HPX collectives over the MPI parcelport; XLA's stock schedule).
* ``pipelined`` — the redistribution is split into chunks; chunk c's
  all_to_all is issued while chunk c+1's FFT computes, a software pipeline
  that hides link latency behind MXU work.  Same bytes on the wire, less
  *exposed* time — the TPU-native analogue of the LCI parcelport speedup.
  Spell ``"pipelined:8"`` to override the chunk count inline.
* ``agas`` — all-gather-then-slice: every locality materializes the full
  array and resolves its block through a global index, emulating the
  redundant data movement of implicit AGAS addressing.  Implemented to
  *measure* the overhead the paper plots (Fig. 1, dark blue), not to be
  used.

An exchange is described positionally, matching ``jax.lax.all_to_all``
tiled semantics: "split axis ``split`` into the ``p`` participants, send
block d to participant d, concatenate received blocks along ``concat``".
One implementation therefore serves the 2D slab layout, the 3D pencil
row/column communicators, and the 4D convolution layout.

Communication *planning* also lives here: :func:`plan_comm` (1D slab
decomposition) and :func:`plan_comm_pencil` (2D-mesh pencil decomposition,
one choice per row/column communicator) pick a backend from the roofline
model — FFTW-style planning applied to the paper's parcelport choice.
"""

from __future__ import annotations

import functools
from typing import Dict, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from . import algo

Complex = algo.Complex

COMM_BACKENDS = ("collective", "pipelined", "agas")


def padded_half(m: int, p: int) -> int:
    """Column count after r2c (m//2+1) padded up to a multiple of p."""
    mh = m // 2 + 1
    return ((mh + p - 1) // p) * p


# ---------------------------------------------------------------------------
# pair-valued collective primitives (the only place raw collectives appear)
# ---------------------------------------------------------------------------


def a2a_pair(c: Complex, axis_name: str, split: int, concat: int) -> Complex:
    """Tiled all_to_all of an (re, im) pair."""
    f = functools.partial(jax.lax.all_to_all, axis_name=axis_name,
                          split_axis=split, concat_axis=concat, tiled=True)
    return f(c[0]), f(c[1])


def all_gather_pair(c: Complex, axis_name: str, axis: int = 0,
                    tiled: bool = False) -> Complex:
    """all_gather of a pair of same-layout arrays (spectrum halves, or any
    payload+metadata pair such as int8 gradients + scales)."""
    f = functools.partial(jax.lax.all_gather, axis_name=axis_name,
                          axis=axis, tiled=tiled)
    return f(c[0]), f(c[1])


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


class CommBackend:
    """One redistribution strategy for pair-valued sharded exchanges."""

    name: str = "abstract"

    def exchange(self, c: Complex, axis_name: str, *, split: int,
                 concat: int, p: int) -> Complex:
        """Redistribute: split ``split`` over the ``p`` participants of
        ``axis_name``, concatenate received blocks along ``concat``."""
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}()"


class CollectiveBackend(CommBackend):
    """Monolithic all_to_all (MPI-parcelport analogue)."""

    name = "collective"

    def exchange(self, c, axis_name, *, split, concat, p):
        return a2a_pair(c, axis_name, split, concat)


class PipelinedBackend(CommBackend):
    """Chunked all_to_all software pipeline (LCI-parcelport analogue).

    Each participant's DESTINATION block of width W = size(split)/p is cut
    into ``chunks`` sub-blocks; sub-block c of every destination is
    exchanged by its own all_to_all, so the concatenation of received chunks
    along ``split`` reproduces the monolithic layout exactly.  XLA emits
    independent all-to-all-start/done pairs, so on hardware chunk c's
    transfer overlaps chunk c+1's residual compute; bytes on the wire are
    identical to the monolithic collective, but the exposed communication
    time shrinks.
    """

    name = "pipelined"

    def __init__(self, chunks: int = 4):
        self.chunks = chunks

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"PipelinedBackend(chunks={self.chunks})"

    def exchange(self, c, axis_name, *, split, concat, p):
        shape = c[0].shape
        w = shape[split] // p
        chunks = max(1, min(self.chunks, w))
        while w % chunks:
            chunks -= 1
        if chunks == 1:
            return a2a_pair(c, axis_name, split, concat)
        wc = w // chunks
        grouped = shape[:split] + (p, w) + shape[split + 1:]
        flat = shape[:split] + (p * wc,) + shape[split + 1:]
        g = (c[0].reshape(grouped), c[1].reshape(grouped))
        outs = []
        for k in range(chunks):
            piece = tuple(
                jax.lax.dynamic_slice_in_dim(a, k * wc, wc, split + 1)
                .reshape(flat) for a in g)
            outs.append(a2a_pair(piece, axis_name, split, concat))
        return (jnp.concatenate([o[0] for o in outs], axis=split),
                jnp.concatenate([o[1] for o in outs], axis=split))


class AgasBackend(CommBackend):
    """AGAS emulation: implicit addressing = replicate-then-slice.

    Every locality gathers the FULL array (p x the necessary bytes) along
    the concat direction and then resolves its block through a global index
    — the redundant data movement the paper measures for the AGAS variant.
    """

    name = "agas"

    def exchange(self, c, axis_name, *, split, concat, p):
        re, im = all_gather_pair(c, axis_name, axis=concat, tiled=True)
        i = jax.lax.axis_index(axis_name)
        w = re.shape[split] // p
        return (jax.lax.dynamic_slice_in_dim(re, i * w, w, split),
                jax.lax.dynamic_slice_in_dim(im, i * w, w, split))


# ---------------------------------------------------------------------------
# resolution: strings (and per-axis collections of strings) -> backends
# ---------------------------------------------------------------------------

CommSpec = Union[str, CommBackend]


def get_backend(spec: CommSpec, chunks: int = 4) -> CommBackend:
    """Resolve a backend spec: a :class:`CommBackend` instance, or one of
    ``"collective"`` / ``"pipelined"`` (optionally ``"pipelined:<chunks>"``)
    / ``"agas"``."""
    if isinstance(spec, CommBackend):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"comm spec must be str or CommBackend, got {spec!r}")
    name, _, arg = spec.partition(":")
    if name == "collective":
        return CollectiveBackend()
    if name == "pipelined":
        return PipelinedBackend(int(arg) if arg else chunks)
    if name == "agas":
        return AgasBackend()
    raise ValueError(f"comm backend {spec!r}; options {COMM_BACKENDS}")


def resolve_axis_backends(comm, axes: Sequence[str],
                          chunks: int = 4) -> Tuple[CommBackend, ...]:
    """Per-mesh-axis backend resolution for multi-axis (pencil) paths.

    ``comm`` may be a single spec (applied to every axis), a sequence with
    one spec per axis (ordered as ``axes``), or a dict keyed by mesh-axis
    name (missing axes default to ``"collective"``).
    """
    if isinstance(comm, dict):
        unknown = set(comm) - set(axes)
        if unknown:
            raise ValueError(
                f"per-axis comm has unknown mesh axes {sorted(unknown)}; "
                f"valid axes: {tuple(axes)}")
        return tuple(get_backend(comm.get(a, "collective"), chunks)
                     for a in axes)
    if isinstance(comm, (list, tuple)):
        if len(comm) != len(axes):
            raise ValueError(
                f"per-axis comm needs {len(axes)} entries for {axes}, "
                f"got {len(comm)}")
        return tuple(get_backend(s, chunks) for s in comm)
    return tuple(get_backend(comm, chunks) for _ in axes)


# ---------------------------------------------------------------------------
# communication-aware planning (FFTW-style planning applied to the paper's
# parcelport choice: pick the comm backend from the roofline model)
# ---------------------------------------------------------------------------


def plan_comm(n: int, m: int, p: int, hw=None,
              overlap_capable: bool = True) -> str:
    """Choose the communication backend for an (n x m) slab FFT on p chips.

    Cost model (per device, per exchange):
      collective: wire = 2 * (p-1)/p * slab_bytes           (two all_to_alls)
      pipelined:  same wire, exposed time ~ 1/chunks, but adds one slab
                  read+write of HBM traffic for the chunk copies
      agas:       wire = 2 * (p-1) * slab_bytes              (never chosen)
    The monolithic collective wins when the exchange is small relative to
    compute (it fuses best); pipelining wins when exposed-comm would exceed
    ~20% of the local FFT compute time and overlap hardware exists.
    """
    from .plan import TPU_V5E
    hw = hw or TPU_V5E
    mh_pad = padded_half(m, p)
    slab_bytes = (n / p) * mh_pad * 8.0
    wire = 2.0 * (p - 1) / p * slab_bytes
    t_comm = wire / hw.link_bw
    # local compute: four-step matmul flops for rows + cols
    flops = 8.0 * (n / p) * mh_pad * (
        sum(algo.default_factorization(m // 2))
        + sum(algo.default_factorization(n)))
    t_comp = flops / hw.flops
    if overlap_capable and t_comm > 0.2 * t_comp:
        return "pipelined"
    return "collective"


def plan_comm_pencil(shape: Tuple[int, int, int],
                     mesh_shape: Tuple[int, int], hw=None,
                     overlap_capable: bool = True,
                     kind: str = "c2c") -> Tuple[str, str]:
    """Choose per-axis comm backends for a pencil FFT on a (p0, p1) mesh.

    Unlike the 1D slab model, pencil exchanges run inside row/column
    communicators: the Z<->Y exchange stays within the p1-sized row
    communicator (mesh axis 1) and overlaps the Y-stage FFTs; the Y<->X
    exchange stays within the p0-sized column communicator (mesh axis 0)
    and overlaps the X-stage FFTs.  Each communicator is planned
    independently against the stage it can hide behind:

      wire_axis = (p_axis - 1)/p_axis * pencil_bytes
      t_comp    = four-step matmul flops of that stage / hw.flops

    Returns ``(backend_for_mesh_axis_0, backend_for_mesh_axis_1)``, the
    order :func:`repro.core.dfft.fft3_pencil` consumes.
    """
    from .plan import TPU_V5E
    hw = hw or TPU_V5E
    nx, ny, nz = shape
    p0, p1 = mesh_shape
    nz_eff = padded_half(nz, p1) if kind in ("r2c", "c2r") else nz
    # the local pencil: an (re, im) f32 pair, constant across both exchanges
    pencil_bytes = (nx / p0) * (ny / p1) * nz_eff * 8.0
    elems = pencil_bytes / 8.0

    def choose(p: int, n_axis: int) -> str:
        if p <= 1:
            return "collective"
        wire = (p - 1) / p * pencil_bytes
        t_comm = wire / hw.link_bw
        flops = 8.0 * elems * sum(algo.default_factorization(n_axis))
        t_comp = flops / hw.flops
        if overlap_capable and t_comm > 0.2 * t_comp:
            return "pipelined"
        return "collective"

    # mesh axis 0's exchange feeds the X-stage; mesh axis 1's the Y-stage
    return choose(p0, nx), choose(p1, ny)
