"""Version-compatibility shims for the installed JAX.

The codebase targets the modern ``jax.shard_map`` entry point (promoted out
of ``jax.experimental`` in JAX 0.5) and its keyword spelling.  On JAX 0.4.x
the function only exists at ``jax.experimental.shard_map.shard_map`` and
takes the older keywords: ``check_rep`` instead of ``check_vma``, and
``auto`` (the set of axes left to GSPMD) instead of ``axis_names`` (the set
of axes made manual).  Every ``shard_map`` call site in the repo goes
through :func:`shard_map` below so the translation lives in exactly one
place.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Set

import jax

__all__ = ["shard_map", "batched_spec", "pvary", "ring_shift", "scan_carry",
           "partial_manual_region", "legacy_partial_manual"]

_TLS = threading.local()


@contextlib.contextmanager
def partial_manual_region():
    """Mark code traced within as living inside a PARTIAL-manual shard_map
    region (manual over some mesh axes, auto/GSPMD over the rest).

    JAX 0.4.x's SPMD partitioner cannot lower collective-permute,
    all_to_all, ``axis_index``'s partition-id, or while-loops whose bodies
    gather region inputs when auto axes remain in scope — only psum-family
    collectives survive.  :func:`ring_shift` and :func:`scan_carry` switch
    to partitioner-safe (but costlier) fallbacks only inside this context
    AND only on old JAX; everywhere else they emit the native ops.  Wrap
    the *invocation* of the shard_map-wrapped callable (tracing happens
    there), as :func:`repro.parallel.pipelined_lm.pipelined_loss_fn` does.
    """
    prev = getattr(_TLS, "partial_manual", False)
    _TLS.partial_manual = True
    try:
        yield
    finally:
        _TLS.partial_manual = prev


def legacy_partial_manual() -> bool:
    """True when tracing inside :func:`partial_manual_region` on a JAX
    whose partitioner needs the fallbacks (0.4.x)."""
    return (not hasattr(jax, "shard_map")
            and getattr(_TLS, "partial_manual", False))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names: Optional[Set] = None):
    """``jax.shard_map`` with a fallback for JAX 0.4.x.

    Accepts the modern keywords; on older JAX they are translated to the
    experimental API (``check_vma`` -> ``check_rep``; ``axis_names`` -> the
    complement ``auto`` set of the mesh's axis names).
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def batched_spec(spec, batch_ndim: int):
    """Prepend ``batch_ndim`` replicated (None) dims to a PartitionSpec.

    The one batching convention for every shard_map'd transform: leading
    batch axes are never sharded by the FFT layer, so a spec written for the
    unbatched layout extends to any batch rank.  Shared by the slab/pencil
    executors in :mod:`repro.core.dfft` and the sequence-sharded convolution
    in :mod:`repro.core.fftconv`.
    """
    from jax.sharding import PartitionSpec
    if batch_ndim <= 0:
        return spec
    return PartitionSpec(*((None,) * batch_ndim + tuple(spec)))


def pvary(x, axis_names):
    """``jax.lax.pvary`` (mark a value as varying over manual mesh axes for
    the VMA type system, JAX >= 0.5).  JAX 0.4.x has no VMA tracking, so the
    operation degenerates to the identity there."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x


def ring_shift(out, axis_name, me, s):
    """Send ``out`` one step along the ring ((i -> i+1) mod s) over
    ``axis_name``; receiver i gets stage i-1's value.

    Normally a plain ``ppermute``.  Inside a 0.4.x partial-manual region
    (see :func:`partial_manual_region`) collective-permute cannot lower,
    so the fallback routes the shift through a psum of a one-hot-slotted
    buffer: sender i writes ``out`` into slot i+1, the psum superposes
    all slots, receiver i reads slot i.  Same ring semantics, s x the
    wire bytes; only taken where nothing cheaper lowers.  ``me`` must be
    the caller's stage index — thread it in as DATA (an iota sharded over
    the pipeline axis) when auto axes are present, since ``axis_index``
    itself cannot lower there.
    """
    if not legacy_partial_manual():
        perm = [(i, (i + 1) % s) for i in range(s)]
        return jax.lax.ppermute(out, axis_name, perm)
    import jax.numpy as jnp
    slot = (me + 1) % s
    contrib = jnp.zeros((s,) + out.shape, out.dtype)
    contrib = jax.lax.dynamic_update_index_in_dim(contrib, out, slot, 0)
    g = jax.lax.psum(contrib, axis_name)
    return jax.lax.dynamic_index_in_dim(g, me, 0, keepdims=False)


def scan_carry(body, init, xs):
    """``jax.lax.scan`` threading only the carry (ys discarded) — returns
    ``(carry, None)``.

    Inside a 0.4.x partial-manual region the while-loop trips the same
    partitioner CHECK as the collectives above (the loop body gathers
    per-iteration slices of region inputs while manual-subgroup
    collectives live in the surrounding computation), so there — and only
    there — the loop unrolls.  Use it for loops that may run inside such
    regions and whose trip count stays small and static (per-stage layer
    stacks, flash-attention kv blocks); everywhere else it is exactly
    ``lax.scan``.
    """
    if not legacy_partial_manual():
        carry, _ = jax.lax.scan(body, init, xs)
        return carry, None
    import jax.tree_util as jtu
    n = jtu.tree_leaves(xs)[0].shape[0]
    carry = init
    for i in range(n):
        carry, _ = body(carry, jtu.tree_map(lambda a, _i=i: a[_i], xs))
    return carry, None
