"""Jitted public wrapper for the fused complex multiply kernel."""

from __future__ import annotations

import functools
import os

import jax

from .twiddle import complex_multiply_pallas


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block",))
def complex_multiply(a, b, *, block: int = 1024):
    return complex_multiply_pallas(a, b, block=block,
                                   interpret=_interpret_default())
