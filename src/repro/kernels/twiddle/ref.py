"""Pure-jnp oracle for the fused complex multiply kernel."""

from __future__ import annotations

from repro.core.algo import cmul


def complex_multiply_ref(a, b):
    return cmul(a, b)
