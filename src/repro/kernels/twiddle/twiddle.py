"""Pallas TPU kernel: fused complex pointwise multiply (twiddle / spectral
filter application).

Used standalone by the FFT-convolution pipeline (y_hat = x_hat * k_hat in
frequency space) where fusing the 6-op complex product into one VMEM pass
halves HBM traffic versus two separate jnp multiplies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cmul_kernel(ar_ref, ai_ref, br_ref, bi_ref, or_ref, oi_ref):
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    or_ref[...] = ar * br - ai * bi
    oi_ref[...] = ar * bi + ai * br


def complex_multiply_pallas(a, b, *, block: int = 1024, interpret: bool = True):
    """Elementwise (re, im) * (re, im). b broadcasts over leading dims of a."""
    ar, ai = a
    br, bi = b
    br = jnp.broadcast_to(br, ar.shape)
    bi = jnp.broadcast_to(bi, ai.shape)
    shape = ar.shape
    flat = 1
    for s in shape:
        flat *= s
    bk = min(block, flat)
    while flat % bk:
        bk -= 1
    spec = pl.BlockSpec((bk,), lambda i: (i,))
    orr, oi = pl.pallas_call(
        _cmul_kernel,
        grid=(flat // bk,),
        in_specs=[spec] * 4,
        out_specs=[spec] * 2,
        out_shape=[jax.ShapeDtypeStruct((flat,), ar.dtype)] * 2,
        interpret=interpret,
    )(ar.reshape(flat), ai.reshape(flat), br.reshape(flat), bi.reshape(flat))
    return orr.reshape(shape), oi.reshape(shape)
