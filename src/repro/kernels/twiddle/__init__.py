from .ops import complex_multiply
from .ref import complex_multiply_ref

__all__ = ["complex_multiply", "complex_multiply_ref"]
