from .ops import transpose
from .ref import transpose_ref

__all__ = ["transpose", "transpose_ref"]
