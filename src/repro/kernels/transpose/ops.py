"""Jitted public wrapper for the tiled transpose kernel."""

from __future__ import annotations

import functools
import os

import jax

from .transpose import transpose_tiled


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block",))
def transpose(x: jax.Array, *, block: int = 128) -> jax.Array:
    return transpose_tiled(x, block=block, interpret=_interpret_default())
