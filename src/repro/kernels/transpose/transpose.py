"""Pallas TPU kernel: tiled 2D transpose with write-contiguous blocks.

The paper's key shared-memory insight (§3.2): place the barrier so transpose
tasks WRITE contiguous memory.  On TPU that becomes: each grid step reads a
(bi, bj) tile and writes the (bj, bi) tile of the output — the *output*
BlockSpec walks row-major over the transposed array, so every store is a
contiguous lane-aligned VMEM->HBM burst, and the strided access pattern is
confined to the HBM->VMEM read side where the DMA engine amortizes it.

Used by the FFT pipelines between dimension passes and by the distributed
slab rearrange.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = jnp.swapaxes(x_ref[...], -1, -2)


def transpose_tiled(x: jax.Array, *, block: int = 128,
                    interpret: bool = True) -> jax.Array:
    """(..., n, m) -> (..., m, n). Batch dims are grid-mapped."""
    *batch, n, m = x.shape
    b = 1
    for s in batch:
        b *= s
    x3 = x.reshape(b, n, m)
    bi = min(block, n)
    bj = min(block, m)
    while n % bi:
        bi -= 1
    while m % bj:
        bj -= 1

    grid = (b, m // bj, n // bi)  # output-major walk: write-contiguous
    out = pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bi, bj), lambda k, j, i: (k, i, j))],
        out_specs=pl.BlockSpec((1, bj, bi), lambda k, j, i: (k, j, i)),
        out_shape=jax.ShapeDtypeStruct((b, m, n), x.dtype),
        interpret=interpret,
    )(x3)
    return out.reshape(*batch, m, n)
