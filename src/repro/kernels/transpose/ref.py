"""Pure-jnp oracle for the tiled transpose kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def transpose_ref(x: jax.Array) -> jax.Array:
    return jnp.swapaxes(x, -1, -2)
