from .ops import fft_four_step
from .ref import fft_four_step_ref

__all__ = ["fft_four_step", "fft_four_step_ref"]
