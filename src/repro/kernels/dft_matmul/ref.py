"""Pure-jnp oracle for the dft_matmul Pallas kernel."""

from __future__ import annotations

from typing import Tuple

import jax

from repro.core import algo


def fft_four_step_ref(x: Tuple[jax.Array, jax.Array],
                      factors: Tuple[int, int],
                      *, karatsuba: bool = False,
                      permuted: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Reference: the core four-step algorithm (itself numpy-validated)."""
    return algo.fft(x, factors=factors, karatsuba=karatsuba, permuted=permuted)
