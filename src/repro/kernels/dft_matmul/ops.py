"""Jitted public wrapper for the dft_matmul kernel.

``interpret`` defaults to True because this container is CPU-only; real-TPU
deployments flip REPRO_PALLAS_INTERPRET=0 (the launcher does this when
jax.default_backend() == 'tpu').
"""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax

from .dft_matmul import fft_four_step_pallas


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("factors", "karatsuba", "permuted",
                                             "block_rows"))
def fft_four_step(x: Tuple[jax.Array, jax.Array], factors: Tuple[int, int],
                  *, karatsuba: bool = False, permuted: bool = False,
                  block_rows: int = 8) -> Tuple[jax.Array, jax.Array]:
    return fft_four_step_pallas(x, tuple(factors), karatsuba=karatsuba,
                                permuted=permuted, block_rows=block_rows,
                                interpret=_interpret_default())
