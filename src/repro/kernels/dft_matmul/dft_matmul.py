"""Pallas TPU kernel: batched four-step FFT as MXU matmuls with fused twiddle.

One grid step processes a (block_rows, n1, n2) tile of the batch entirely in
VMEM: two complex DFT matmuls (4 real MXU matmuls each, or 3 with Karatsuba)
with the twiddle multiply fused between them — no HBM round-trip between the
four steps (the CPU version pays one per stage).

This is the paper's "task": block_rows is the task size (rows per task), and
the kernel IS the bulk-synchronous `for_loop` body — all rows of a block run
one fused schedule, matching the paper's winning variant.

Layout notes (TPU):
  * n2 sits in the lane dimension — plans choose n2 as a multiple of 128.
  * n1 sits in sublanes; the step-1 contraction is expressed with
    dot_general over the middle axis so Mosaic keeps the lane layout.
  * DFT matrices / twiddles are f32 VMEM residents shared by all rows of the
    block; f32 accumulate via preferred_element_type.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdot(ar, ai, br, bi, karatsuba: bool):
    """Complex contraction: (..., k) x (k, m) -> (..., m)."""
    dn = (((ar.ndim - 1,), (0,)), ((), ()))
    mm = functools.partial(jax.lax.dot_general, dimension_numbers=dn,
                           preferred_element_type=jnp.float32)
    if karatsuba:
        p1 = mm(ar, br)
        p2 = mm(ai, bi)
        p3 = mm(ar + ai, br + bi)
        return p1 - p2, p3 - p1 - p2
    return mm(ar, br) - mm(ai, bi), mm(ar, bi) + mm(ai, br)


def _four_step_kernel(xr_ref, xi_ref, w1r_ref, w1i_ref, twr_ref, twi_ref,
                      w2r_ref, w2i_ref, or_ref, oi_ref, *,
                      n1: int, n2: int, karatsuba: bool, permuted: bool):
    bm = xr_ref.shape[0]
    ar = xr_ref[...].reshape(bm, n1, n2)
    ai = xi_ref[...].reshape(bm, n1, n2)

    # step 1: DFT_n1 along axis 1. Work on the (bm, n2, n1) view so the
    # contraction is a last-axis MXU matmul.
    art = jnp.swapaxes(ar, 1, 2)
    ait = jnp.swapaxes(ai, 1, 2)
    btr, bti = _cdot(art, ait, w1r_ref[...], w1i_ref[...], karatsuba)
    br = jnp.swapaxes(btr, 1, 2)          # (bm, k1, n2)
    bi = jnp.swapaxes(bti, 1, 2)

    # step 2: fused twiddle T[k1, n2] — stays in VREGs
    twr = twr_ref[...]
    twi = twi_ref[...]
    cr = br * twr - bi * twi
    ci = br * twi + bi * twr

    # step 3: DFT_n2 along the last (lane) axis
    dr, di = _cdot(cr, ci, w2r_ref[...], w2i_ref[...], karatsuba)

    if permuted:
        or_ref[...] = dr.reshape(bm, n1 * n2)
        oi_ref[...] = di.reshape(bm, n1 * n2)
    else:
        # step 4: digit transpose X[k2*n1 + k1] = D[k1, k2]
        or_ref[...] = jnp.swapaxes(dr, 1, 2).reshape(bm, n1 * n2)
        oi_ref[...] = jnp.swapaxes(di, 1, 2).reshape(bm, n1 * n2)


def fft_four_step_pallas(x: Tuple[jax.Array, jax.Array],
                         factors: Tuple[int, int],
                         *, karatsuba: bool = False, permuted: bool = False,
                         block_rows: int = 8,
                         interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Batched c2c FFT along the last axis; x = (re, im), shape (..., n).

    ``interpret=True`` runs the kernel body on CPU (this container); on real
    TPU pass interpret=False.
    """
    from repro.core import algo

    n1, n2 = factors
    n = n1 * n2
    xr, xi = x
    assert xr.shape[-1] == n, (xr.shape, factors)
    batch_shape = xr.shape[:-1]
    b = 1
    for s in batch_shape:
        b *= s
    xr2 = xr.reshape(b, n)
    xi2 = xi.reshape(b, n)

    bm = min(block_rows, b)
    while b % bm:
        bm -= 1

    w1 = algo.dft_matrix(n1, -1)
    w2 = algo.dft_matrix(n2, -1)
    tw = algo.twiddle_factors(n1, n2, -1)

    grid = (b // bm,)
    data_spec = pl.BlockSpec((bm, n), lambda i: (i, 0))
    const = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))

    kernel = functools.partial(_four_step_kernel, n1=n1, n2=n2,
                               karatsuba=karatsuba, permuted=permuted)
    out_shape = [jax.ShapeDtypeStruct((b, n), jnp.float32)] * 2
    orr, oii = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[data_spec, data_spec,
                  const((n1, n1)), const((n1, n1)),
                  const((n1, n2)), const((n1, n2)),
                  const((n2, n2)), const((n2, n2))],
        out_specs=[data_spec, data_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr2, xi2, w1[0], w1[1], tw[0], tw[1], w2[0], w2[1])
    return orr.reshape(*batch_shape, n), oii.reshape(*batch_shape, n)
