from .ops import fftconv_fused
from .ref import fftconv_fused_ref

__all__ = ["fftconv_fused", "fftconv_fused_ref"]
