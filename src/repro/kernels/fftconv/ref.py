"""Pure-jnp oracle for the fused FFT-convolution kernel."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def fftconv_fused_ref(x: jax.Array, h: jax.Array) -> jax.Array:
    """Circular convolution via the complex FFT (rows of x with filter h)."""
    xf = jnp.fft.fft(x.astype(jnp.float32), axis=-1)
    hf = jnp.fft.fft(h.astype(jnp.float32))
    return jnp.real(jnp.fft.ifft(xf * hf[None, :], axis=-1)).astype(jnp.float32)
