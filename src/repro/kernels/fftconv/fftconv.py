"""Pallas TPU kernel: fused FFT convolution (FlashFFTConv-style).

One grid step performs, entirely in VMEM for a (block_rows, nf) tile:

    spectrum = four_step_fft(x)          # 2 complex matmuls + twiddle
    spectrum *= filter_spectrum          # fused pointwise complex multiply
    y = inverse_four_step(spectrum)      # 2 complex matmuls + conj twiddle

i.e. the entire y = ifft(fft(x) * H) pipeline with ONE HBM read and ONE HBM
write per element, where the unfused jnp path pays ~6 HBM round-trips (fft
passes, pointwise, ifft passes) — this is the memory-pass fix identified in
EXPERIMENTS.md §Perf-A.  The digit transposes are skipped on BOTH sides
(permuted frequency order; the pointwise product commutes with the
permutation), so no in-kernel transposes are needed at all.

The filter spectrum is precomputed once per filter in permuted order by
``filter_spectrum_permuted`` (ref-validated) and broadcast to all rows of
the grid.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdot(ar, ai, br, bi):
    dn = (((ar.ndim - 1,), (0,)), ((), ()))
    mm = functools.partial(jax.lax.dot_general, dimension_numbers=dn,
                           preferred_element_type=jnp.float32)
    return mm(ar, br) - mm(ai, bi), mm(ar, bi) + mm(ai, br)


def _fft2f(ar, ai, w1, tw, w2, n1, n2):
    """Two-factor four-step FFT on (bm, n1, n2) blocks, permuted output."""
    art = jnp.swapaxes(ar, 1, 2)
    ait = jnp.swapaxes(ai, 1, 2)
    btr, bti = _cdot(art, ait, w1[0], w1[1])       # DFT along n1
    br = jnp.swapaxes(btr, 1, 2)
    bi = jnp.swapaxes(bti, 1, 2)
    cr = br * tw[0] - bi * tw[1]                    # twiddle (k1, n2)
    ci = br * tw[1] + bi * tw[0]
    return _cdot(cr, ci, w2[0], w2[1])              # DFT along n2 -> C[k1,k2]


def _ifft2f(cr, ci, w1i, twi, w2i, n1, n2):
    """Inverse consuming permuted order (no transposes), unnormalized."""
    br, bi = _cdot(cr, ci, w2i[0], w2i[1])          # inv DFT along k2
    er = br * twi[0] - bi * twi[1]                  # conj twiddle
    ei = br * twi[1] + bi * twi[0]
    ert = jnp.swapaxes(er, 1, 2)
    eit = jnp.swapaxes(ei, 1, 2)
    atr, ati = _cdot(ert, eit, w1i[0], w1i[1])      # inv DFT along k1
    return jnp.swapaxes(atr, 1, 2), jnp.swapaxes(ati, 1, 2)


def _fftconv_kernel(x_ref, hr_ref, hi_ref,
                    w1r, w1i, twr, twi, w2r, w2i,
                    v1r, v1i, vtr, vti, v2r, v2i,
                    o_ref, *, n1: int, n2: int):
    bm = x_ref.shape[0]
    nf = n1 * n2
    xr = x_ref[...].reshape(bm, n1, n2).astype(jnp.float32)
    xi = jnp.zeros_like(xr)
    fr, fi = _fft2f(xr, xi, (w1r[...], w1i[...]), (twr[...], twi[...]),
                    (w2r[...], w2i[...]), n1, n2)
    hr = hr_ref[...].reshape(1, n1, n2)
    hi = hi_ref[...].reshape(1, n1, n2)
    pr = fr * hr - fi * hi                          # fused spectral multiply
    pi = fr * hi + fi * hr
    yr, _ = _ifft2f(pr, pi, (v1r[...], v1i[...]), (vtr[...], vti[...]),
                    (v2r[...], v2i[...]), n1, n2)
    o_ref[...] = (yr / nf).reshape(bm, nf)


def fftconv_fused_pallas(x: jax.Array, h_spec: Tuple[jax.Array, jax.Array],
                         factors: Tuple[int, int], *, block_rows: int = 8,
                         interpret: bool = True) -> jax.Array:
    """Circular convolution of real rows x (B, nf) with a filter given as a
    PERMUTED-order spectrum pair (nf,).  Returns real (B, nf)."""
    from repro.core import algo

    n1, n2 = factors
    nf = n1 * n2
    b = x.shape[0]
    assert x.shape == (b, nf)
    bm = min(block_rows, b)
    while b % bm:
        bm -= 1

    w1 = algo.dft_matrix(n1, -1)
    w2 = algo.dft_matrix(n2, -1)
    tw = algo.twiddle_factors(n1, n2, -1)
    v1 = algo.dft_matrix(n1, +1)
    v2 = algo.dft_matrix(n2, +1)
    vt = algo.twiddle_factors(n1, n2, +1)

    data = pl.BlockSpec((bm, nf), lambda i: (i, 0))
    vec = pl.BlockSpec((nf,), lambda i: (0,))
    c2 = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))

    kernel = functools.partial(_fftconv_kernel, n1=n1, n2=n2)
    return pl.pallas_call(
        kernel,
        grid=(b // bm,),
        in_specs=[data, vec, vec,
                  c2((n1, n1)), c2((n1, n1)), c2((n1, n2)), c2((n1, n2)),
                  c2((n2, n2)), c2((n2, n2)),
                  c2((n1, n1)), c2((n1, n1)), c2((n1, n2)), c2((n1, n2)),
                  c2((n2, n2)), c2((n2, n2))],
        out_specs=data,
        out_shape=jax.ShapeDtypeStruct((b, nf), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), h_spec[0], h_spec[1],
      w1[0], w1[1], tw[0], tw[1], w2[0], w2[1],
      v1[0], v1[1], vt[0], vt[1], v2[0], v2[1])


def filter_spectrum_permuted(h: jax.Array, factors: Tuple[int, int]):
    """Real filter (nf,) -> permuted-order spectrum pair, matching the
    kernel's internal FFT schedule."""
    from repro.core import algo
    hp = algo.fft((h.astype(jnp.float32), jnp.zeros_like(h, jnp.float32)),
                  factors=factors, permuted=True)
    return hp
