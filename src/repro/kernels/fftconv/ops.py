"""Jitted public wrapper for the fused FFT-convolution kernel."""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax

from .fftconv import fftconv_fused_pallas, filter_spectrum_permuted


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("factors", "block_rows"))
def fftconv_fused(x: jax.Array, h: jax.Array, factors: Tuple[int, int],
                  *, block_rows: int = 8) -> jax.Array:
    """y[b] = circular_conv(x[b], h), fused in VMEM. x (B, nf); h (nf,)."""
    h_spec = filter_spectrum_permuted(h, factors)
    return fftconv_fused_pallas(x, h_spec, factors, block_rows=block_rows,
                                interpret=_interpret_default())
