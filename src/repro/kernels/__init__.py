"""Pallas TPU kernels for the FFT compute hot spots (validated in
interpret mode against pure-jnp oracles in tests/):

  dft_matmul — fused four-step FFT (2 complex MXU matmuls + twiddle in VMEM)
  transpose  — write-contiguous tiled transpose (the paper's optimized
               transpose, as a BlockSpec layout)
  twiddle    — fused complex pointwise multiply (spectral filters)
  fftconv    — fully fused y = ifft(fft(x) * H): one HBM read + one write
               per element (FlashFFTConv-style; the §Perf-A memory fix)
"""

from .dft_matmul import fft_four_step, fft_four_step_ref
from .fftconv import fftconv_fused, fftconv_fused_ref
from .transpose import transpose, transpose_ref
from .twiddle import complex_multiply, complex_multiply_ref

__all__ = [
    "fft_four_step", "fft_four_step_ref",
    "fftconv_fused", "fftconv_fused_ref",
    "transpose", "transpose_ref",
    "complex_multiply", "complex_multiply_ref",
]
