"""Fault-tolerant checkpointing: async, atomic, mesh-agnostic.

Design points for 1000-node operation (scaled to this container):

* **Atomic**: write to ``step_XXXX.tmp`` then ``os.replace`` — a crash
  mid-save never corrupts the latest checkpoint.
* **Async**: device->host transfer happens synchronously (cheap), the disk
  write runs on a background thread so the train loop is not blocked (the
  paper's async-task lesson applied where it *does* pay: I/O, not compute).
* **Mesh-agnostic (elastic)**: arrays are saved logically (full global
  value); ``restore`` device_puts onto whatever sharding the *new* mesh
  prescribes.  Restarting 512-chip training on 256 chips is a restore with
  different rules — tested in tests/test_runtime.py.  (On a real multi-host
  pod each host saves its addressable shards + a manifest; the logical-save
  path here is the single-process specialization.)
* **keep_n** garbage collection, "latest" pointer file, data-iterator step
  and RNG captured alongside arrays.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.save_seconds = 0.0
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None) -> None:
        t0 = time.perf_counter()
        flat, _ = _flatten_with_paths(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        self.wait()                                             # one in flight
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra or {})
        self.save_seconds = time.perf_counter() - t0

    def _write(self, step: int, host: Dict[str, np.ndarray],
               extra: Dict[str, Any]) -> None:
        path = os.path.join(self.dir, f"step_{step:08d}.npz")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **{k.replace("/", "\x1f"): v for k, v in host.items()})
        os.replace(tmp, path)
        man = {"step": step, "extra": extra,
               "keys": sorted(host.keys()),
               "time": time.time()}
        mtmp = path + ".json.tmp"
        with open(mtmp, "w") as f:
            json.dump(man, f)
        os.replace(mtmp, path + ".json")
        with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.dir, "latest.tmp"),
                   os.path.join(self.dir, "latest"))
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n]:
            for suffix in (".npz", ".npz.json"):
                try:
                    os.remove(os.path.join(self.dir, f"step_{s:08d}{suffix}"))
                except OSError:
                    pass

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("step_") and f.endswith(".npz"):
                out.append(int(f[5:13]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "latest")
        if os.path.exists(p):
            with open(p) as f:
                s = int(f.read().strip())
            if os.path.exists(os.path.join(self.dir, f"step_{s:08d}.npz")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None):
        """Restore onto the structure of ``like``; optional sharding tree
        (same structure) re-shards for the current mesh (elastic restart)."""
        path = os.path.join(self.dir, f"step_{step:08d}.npz")
        data = np.load(path)
        flat_like, treedef = _flatten_with_paths(like)
        flat_sh = None
        if shardings is not None:
            flat_sh, _ = _flatten_with_paths(shardings)
        out = {}
        for k in flat_like:
            arr = data[k.replace("/", "\x1f")]
            if flat_sh is not None:
                out[k] = jax.device_put(arr, flat_sh[k])
            else:
                out[k] = jax.numpy.asarray(arr)
        leaves = [out[k] for k in flat_like]
        with open(path + ".json") as f:
            man = json.load(f)
        return jax.tree_util.tree_unflatten(treedef, leaves), man["extra"]
