from .pipeline import SyntheticDataset, batch_specs

__all__ = ["SyntheticDataset", "batch_specs"]
