"""Synthetic data pipeline: deterministic, shardable, restartable.

Every batch is a pure function of (seed, step), so the iterator "state" is
just the step counter — checkpoint/restart resumes bit-identically, and any
host can materialize exactly its shard (the addressable slice of the global
batch) without coordination.  That is the property a 1000-node input
pipeline needs; swapping in a real tokenized corpus only changes
``_tokens_at``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig, ShapeConfig


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, rules: Dict) -> Dict:
    """PartitionSpecs for one batch (mirrors input_specs structures)."""
    dp = rules.get("dp")
    if shape.kind == "train" or shape.kind == "prefill":
        if cfg.frontend:
            specs = {"embeds": P(dp, None, None), "labels": P(dp, None)}
        else:
            specs = {"tokens": P(dp, None), "labels": P(dp, None)}
        if cfg.rope == "mrope":
            specs["positions"] = P(None, dp, None)
        return specs
    # decode: one token per sequence
    if cfg.frontend:
        return {"embeds": P(dp, None, None)}
    return {"tokens": P(dp, None)}


@dataclasses.dataclass
class SyntheticDataset:
    cfg: ArchConfig
    shape: ShapeConfig
    seed: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, step))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Global (unsharded) numpy batch for ``step``."""
        rng = self._rng(step)
        b, s = self.shape.global_batch, self.shape.seq_len
        if self.shape.kind == "decode":
            s_tok = 1
        else:
            s_tok = s
        out: Dict[str, np.ndarray] = {}
        if self.cfg.frontend:
            out["embeds"] = (rng.standard_normal(
                (b, s_tok, self.cfg.d_model)).astype(np.float32) * 0.02)
        else:
            out["tokens"] = rng.integers(
                0, self.cfg.vocab_size, (b, s_tok), dtype=np.int32)
        if self.shape.kind in ("train", "prefill"):
            toks = out.get("tokens")
            if toks is not None:
                labels = np.concatenate(
                    [toks[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
            else:
                labels = rng.integers(0, self.cfg.vocab_size, (b, s_tok),
                                      dtype=np.int32)
            out["labels"] = labels
            if self.cfg.rope == "mrope":
                pos = np.broadcast_to(np.arange(s_tok, dtype=np.int32),
                                      (b, s_tok))
                out["positions"] = np.broadcast_to(pos[None], (3, b, s_tok)).copy()
        return out

    def sharded_batch_at(self, step: int, mesh: jax.sharding.Mesh,
                         rules: Dict) -> Dict[str, jax.Array]:
        """Materialize only this process' addressable shards."""
        global_np = self.batch_at(step)
        specs = batch_specs(self.cfg, self.shape, rules)
        out = {}
        for k, arr in global_np.items():
            sh = NamedSharding(mesh, specs[k])
            out[k] = jax.make_array_from_callback(
                arr.shape, sh, lambda idx, _a=arr: _a[idx])
        return out
