"""Fault-tolerant training driver.

Responsibilities at fleet scale, all exercised in tests on this container:

* build mesh + sharding rules, jit the train step with donated state
* checkpoint every ``ckpt_every`` steps (async, atomic, keep-N)
* restart: resume bit-identically from the latest checkpoint (params, Adam
  moments, data-iterator step)
* elastic restart: restore onto a *different* mesh (device count change)
* straggler watchdog: per-step wall-time EWMA; steps slower than
  ``straggler_factor`` x the EWMA are logged as straggler events (on a real
  fleet this feeds the remediation controller that cordons the slow host —
  here the hook records and continues, per the simulation guidance)
* preemption hook: REPRO_PREEMPT_AT=<step> raises after the checkpoint at
  that step, simulating a SIGTERM'd worker for the restart tests.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticDataset, batch_specs
from repro.models import lm
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.params import sharding_rules
from repro.optim import AdamWConfig, adamw_init, adamw_update, opt_meta
from repro.parallel import make_rules, logical_shardings


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_n: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10
    seed: int = 0
    # gradient accumulation: split the global batch into this many
    # microbatches, scanning loss+grad and summing — same numerics as one
    # big batch, 1/n the activation memory (the standard big-model lever
    # alongside remat/FSDP)
    grad_accum: int = 1


class Trainer:
    def __init__(self, arch: ArchConfig, shape: ShapeConfig,
                 mesh: Optional[jax.sharding.Mesh], tcfg: TrainerConfig,
                 ocfg: Optional[AdamWConfig] = None):
        self.arch = arch
        self.shape = shape
        self.mesh = mesh
        self.tcfg = tcfg
        self.ocfg = ocfg or AdamWConfig()
        self.rules = make_rules(mesh) if mesh is not None else {}
        self.data = SyntheticDataset(arch, shape, seed=tcfg.seed)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep_n=tcfg.keep_n)
        self.straggler_events = []
        self._ewma = None
        self._build()

    # -- step construction ----------------------------------------------------

    def _build(self):
        arch, mesh, rules = self.arch, self.mesh, self.rules
        meta = lm.model_meta(arch)
        self.meta = meta
        self.opt_meta = opt_meta(meta)
        num_groups = 1
        if mesh is not None:
            dp = rules.get("dp")
            axes = (dp,) if isinstance(dp, str) else (dp or ())
            for a in axes:
                num_groups *= mesh.shape[a]
        self.num_groups = max(num_groups, 1)

        accum = max(self.tcfg.grad_accum, 1)

        def loss_and_grad(params, batch):
            with sharding_rules(mesh, rules):
                return jax.value_and_grad(lm.loss_fn, has_aux=True)(
                    params, arch, batch, self.num_groups)

        def train_step(params, opt_state, batch):
            if accum == 1:
                (loss, metrics), grads = loss_and_grad(params, batch)
            else:
                mb = {k: v.reshape((accum, v.shape[0] // accum) + v.shape[1:])
                      if k != "positions" else
                      v.reshape(v.shape[:1] + (accum, v.shape[1] // accum)
                                + v.shape[2:]).swapaxes(0, 1)
                      for k, v in batch.items()}

                def body(carry, micro):
                    g_sum, l_sum = carry
                    (l, _), g = loss_and_grad(params, micro)
                    g_sum = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(a.dtype), g_sum, g)
                    return (g_sum, l_sum + l), None

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(
                    body, (g0, jnp.zeros((), jnp.float32)), mb)
                scale = 1.0 / accum
                grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
                loss = loss * scale
                metrics = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}
            params, opt_state, opt_metrics = adamw_update(
                self.ocfg, grads, params, opt_state)
            metrics = dict(metrics, loss=loss, **opt_metrics)
            return params, opt_state, metrics

        if mesh is not None:
            pspecs = logical_shardings(mesh, meta, rules)
            ospecs = logical_shardings(mesh, self.opt_meta, rules)
            bspecs = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s),
                batch_specs(arch, self.shape, rules),
                is_leaf=lambda x: isinstance(x, P))
            self.param_shardings = pspecs
            self.opt_shardings = ospecs
            self.step_fn = jax.jit(
                train_step,
                in_shardings=(pspecs, ospecs, bspecs),
                out_shardings=(pspecs, ospecs, None),
                donate_argnums=(0, 1))
        else:
            self.param_shardings = None
            self.opt_shardings = None
            self.step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    # -- state init / restore ---------------------------------------------------

    def init_state(self):
        params = lm.init_params(self.arch, jax.random.key(self.tcfg.seed))
        if self.mesh is not None:
            params = jax.tree_util.tree_map(
                jax.device_put, params, self.param_shardings)
        opt_state = adamw_init(params)
        if self.mesh is not None:
            opt_state = jax.tree_util.tree_map(
                jax.device_put, opt_state, self.opt_shardings)
        return params, opt_state, 0

    def restore_or_init(self):
        step = self.ckpt.latest_step()
        if step is None:
            return self.init_state()
        params0 = lm.init_params(self.arch, jax.random.key(self.tcfg.seed))
        opt0 = adamw_init(params0)
        sh = None
        if self.mesh is not None:
            sh = {"params": self.param_shardings, "opt": self.opt_shardings}
        (restored), extra = self.ckpt.restore(
            step, {"params": params0, "opt": opt0}, sh)
        return restored["params"], restored["opt"], extra.get("data_step", step)

    # -- loop ---------------------------------------------------------------------

    def run(self, num_steps: int):
        params, opt_state, start = self.restore_or_init()
        preempt_at = int(os.environ.get("REPRO_PREEMPT_AT", "-1"))
        history = []
        for step in range(start, num_steps):
            t0 = time.perf_counter()
            if self.mesh is not None:
                batch = self.data.sharded_batch_at(step, self.mesh, self.rules)
            else:
                batch = {k: jnp.asarray(v)
                         for k, v in self.data.batch_at(step).items()}
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self._watchdog(step, dt)
            history.append({k: float(v) for k, v in metrics.items()})
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == num_steps:
                self.ckpt.save(step + 1,
                               {"params": params, "opt": opt_state},
                               extra={"data_step": step + 1})
            if preempt_at >= 0 and step + 1 >= preempt_at:
                self.ckpt.wait()
                raise SystemExit(f"simulated preemption at step {step + 1}")
        self.ckpt.wait()
        return params, opt_state, history

    def _watchdog(self, step: int, dt: float):
        if self._ewma is None:
            self._ewma = dt
        if dt > self.tcfg.straggler_factor * self._ewma and step > 2:
            self.straggler_events.append((step, dt, self._ewma))
        self._ewma = 0.9 * self._ewma + 0.1 * dt
