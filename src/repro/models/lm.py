"""Decoder LM assembled from heterogeneous block segments.

The layer stack is declared as (kind, count) segments (config.resolved_
segments).  Each segment's parameters are STACKED along a leading layer axis
and executed with ``lax.scan`` + optional remat — this keeps the HLO size
O(#segments) instead of O(#layers), which is what makes 64-layer 104B-param
dry-runs compile quickly and keeps remat policy uniform at 1000-node scale.

"shared_attn" segments (zamba2) reference one shared parameter set stored at
the top level; each occurrence still owns its KV cache.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


def _scan_unroll() -> bool:
    """Full layer-loop unroll for dry-run analysis: XLA's HloCostAnalysis
    visits a while-loop body ONCE, so flop/collective accounting of a scanned
    stack is low by ~num_layers.  The dry-run sets REPRO_SCAN_UNROLL=1 to get
    truthful roofline numbers; training keeps the rolled loop (small HLO)."""
    return os.environ.get("REPRO_SCAN_UNROLL", "0") not in ("0", "", "false")

from . import blocks, ssm
from .config import ArchConfig
from .params import ParamMeta, init_tree, is_meta, shard_act

# ---------------------------------------------------------------------------
# metadata assembly
# ---------------------------------------------------------------------------


def _layer_meta(cfg: ArchConfig, kind: str) -> Dict[str, Any]:
    if kind in ("attn_mlp", "shared_attn"):
        return {"ln1": blocks.norm_meta(cfg), "attn": blocks.attention_meta(cfg),
                "ln2": blocks.norm_meta(cfg), "mlp": blocks.mlp_meta(cfg)}
    if kind == "attn_moe":
        return {"ln1": blocks.norm_meta(cfg), "attn": blocks.attention_meta(cfg),
                "ln2": blocks.norm_meta(cfg), "moe": blocks.moe_meta(cfg)}
    if kind == "fftconv_mlp":
        return {"ln1": blocks.norm_meta(cfg), "mix": blocks.fftconv_meta(cfg),
                "ln2": blocks.norm_meta(cfg), "mlp": blocks.mlp_meta(cfg)}
    if kind == "mamba2":
        return {"ln": blocks.norm_meta(cfg), "mixer": ssm.mamba2_meta(cfg)}
    if kind == "mlstm":
        return {"ln": blocks.norm_meta(cfg), "mixer": ssm.mlstm_meta(cfg)}
    if kind == "slstm":
        return {"ln": blocks.norm_meta(cfg), "mixer": ssm.slstm_meta(cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def _stack_meta(meta: Dict, count: int) -> Dict:
    return jax.tree_util.tree_map(
        lambda m: ParamMeta((count,) + m.shape, (None,) + m.logical,
                            init=m.init, scale=m.scale, dtype=m.dtype),
        meta, is_leaf=is_meta)


def padded_vocab(cfg: ArchConfig) -> int:
    """Vocab padded to a lane-aligned, TP-divisible multiple (MaxText-style);
    the pad columns are masked to -inf in the logits."""
    return ((cfg.vocab_size + 255) // 256) * 256


def model_meta(cfg: ArchConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, padded_vocab(cfg)
    tree: Dict[str, Any] = {
        "embed": ParamMeta((v, d), ("tp", "fsdp"), scale=0.02),
        "final_norm": blocks.norm_meta(cfg),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamMeta((d, v), ("fsdp", "tp"),
                                    scale=0.02 / math.sqrt(d))
    needs_shared = any(k == "shared_attn" for k, _ in cfg.resolved_segments())
    if needs_shared:
        tree["shared"] = _layer_meta(cfg, "shared_attn")
    for kind, count in cfg.resolved_segments():
        if kind == "shared_attn":
            tree["segments"].append({})
        else:
            tree["segments"].append(
                {"layers": _stack_meta(_layer_meta(cfg, kind), count)})
    if cfg.param_dtype != "float32":
        # serving deployments hold bf16 weights (no optimizer to feed)
        pd = jnp.dtype(cfg.param_dtype)
        tree = jax.tree_util.tree_map(
            lambda m: dataclasses.replace(m, dtype=pd) if is_meta(m) else m,
            tree, is_leaf=is_meta)
    return tree


def init_params(cfg: ArchConfig, key: jax.Array):
    return init_tree(model_meta(cfg), key)


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------


def _block_fwd(cfg: ArchConfig, kind: str, p: Dict, x: jax.Array,
               positions: jax.Array, cache: Optional[Dict],
               num_groups: int) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn_mlp", "attn_moe", "shared_attn"):
        h = blocks.apply_norm(p["ln1"], cfg, x)
        attn_out, new_cache = blocks.attention_fwd(
            p["attn"], cfg, h, positions, cache)
        if cfg.parallel_block:
            # command-r: attention and FFN in parallel off one norm
            mlp_out = blocks.mlp_fwd(p["mlp"], cfg, h)
            return x + attn_out + mlp_out, new_cache, aux
        x = x + attn_out
        h2 = blocks.apply_norm(p["ln2"], cfg, x)
        if kind == "attn_moe":
            moe_out, aux = blocks.moe_fwd(p["moe"], cfg, h2, num_groups)
            return x + moe_out, new_cache, aux
        return x + blocks.mlp_fwd(p["mlp"], cfg, h2), new_cache, aux
    if kind == "fftconv_mlp":
        h = blocks.apply_norm(p["ln1"], cfg, x)
        x = x + blocks.fftconv_fwd(p["mix"], cfg, h)
        h2 = blocks.apply_norm(p["ln2"], cfg, x)
        return x + blocks.mlp_fwd(p["mlp"], cfg, h2), None, aux
    # recurrent mixers
    h = blocks.apply_norm(p["ln"], cfg, x)
    fwd = {"mamba2": ssm.mamba2_fwd, "mlstm": ssm.mlstm_fwd,
           "slstm": ssm.slstm_fwd}[kind]
    out, new_state = fwd(p["mixer"], cfg, h, state=cache)
    return x + out, new_state, aux


def _remat(fn, cfg: ArchConfig):
    if not cfg.remat:
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat_policy == "dots"
              else jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(params: Dict, cfg: ArchConfig, batch: Dict[str, jax.Array],
            num_groups: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V), aux_loss)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if "embeds" in batch:
        x = batch["embeds"].astype(dt)
        bsz, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = shard_act(x, "dp", None, None)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (bsz, s))
    if cfg.rope == "none":
        x = x + _sinusoidal(positions if positions.ndim == 2 else positions[0],
                            cfg.d_model).astype(x.dtype)

    aux_total = jnp.zeros((), jnp.float32)
    for seg, seg_cfg in zip(params["segments"], cfg.resolved_segments()):
        kind, count = seg_cfg
        if kind == "shared_attn":
            body = _remat(
                lambda x_, p_: _block_fwd(cfg, "shared_attn", p_, x_,
                                          positions, None, num_groups)[::2],
                cfg)
            for _ in range(count):
                x, aux = body(x, params["shared"])
                aux_total = aux_total + aux
            continue

        def body(x_, layer_p, _kind=kind):
            x2, _, aux = _block_fwd(cfg, _kind, layer_p, x_, positions,
                                    None, num_groups)
            return x2, aux
        body = _remat(body, cfg)
        x, auxs = jax.lax.scan(lambda c, p: body(c, p), x, seg["layers"],
                                unroll=_scan_unroll())
        aux_total = aux_total + jnp.sum(auxs)

    x = blocks.apply_norm(params["final_norm"], cfg, x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(dt)
    logits = _mask_pad_vocab(cfg, logits)
    logits = shard_act(logits, "dp", None, "tp")
    return logits, aux_total


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    """Absolute sinusoidal position encoding (musicgen-style, rope='none')."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mask_pad_vocab(cfg: ArchConfig, logits: jax.Array) -> jax.Array:
    vp = padded_vocab(cfg)
    if vp == cfg.vocab_size:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < cfg.vocab_size, logits,
                     jnp.asarray(-1e30, logits.dtype))


def loss_fn(params: Dict, cfg: ArchConfig, batch: Dict[str, jax.Array],
            num_groups: int = 1) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, cfg, batch, num_groups)
    labels = batch["labels"]
    # memory-lean xent over the tp-sharded vocab axis: no (B,S,V) one-hot or
    # f32 logits copy — the f32 cast happens inside the fused reductions.
    m = jnp.max(logits, axis=-1).astype(jnp.float32)
    shifted = logits.astype(jnp.float32) - m[..., None]
    logz = m + jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    label_logit = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    label_logit = label_logit.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((logz - label_logit) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# batched prefill: one forward pass that also materializes decode state
# ---------------------------------------------------------------------------


def prefill(params: Dict, cfg: ArchConfig, batch: Dict[str, jax.Array],
            max_len: int, num_groups: int = 1,
            last_index: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the prompt through the stack once, returning (logits at
    ``last_index`` (default: final position), decode cache).  The serving
    engine's prefill — O(1) forward passes per request instead of O(S)
    decode steps.  ``last_index`` (B,) selects the true prompt end when the
    input is right-padded to a length bucket."""
    dt = jnp.dtype(cfg.compute_dtype)
    if "embeds" in batch:
        x = batch["embeds"].astype(dt)
        bsz, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = shard_act(x, "dp", None, None)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (bsz, s))
    if cfg.rope == "none":
        x = x + _sinusoidal(positions if positions.ndim == 2 else positions[0],
                            cfg.d_model).astype(x.dtype)

    kv, hd = cfg.num_kv_heads, cfg.hd
    pad = max_len - s
    assert pad >= 0, (max_len, s)
    new_segments = []
    for seg, seg_cfg in zip(params["segments"], cfg.resolved_segments()):
        kind, count = seg_cfg

        if kind in ("attn_mlp", "attn_moe", "shared_attn"):
            rope = blocks.rope_tables(cfg, positions)

            def body(x_, layer_p, _kind=kind):
                h = blocks.apply_norm(layer_p["ln1"], cfg, x_)
                q, k, v = blocks._qkv(layer_p["attn"], cfg, h, rope)
                out = blocks.flash_attention(q, k, v, causal=True)
                y = jnp.einsum("bshk,hkd->bsd", out,
                               layer_p["attn"]["wo"].astype(x_.dtype),
                               preferred_element_type=blocks._reduce_pe(cfg))
                x2 = x_ + y.astype(x_.dtype)
                h2 = blocks.apply_norm(layer_p["ln2"], cfg, x2)
                if _kind == "attn_moe":
                    mo, _ = blocks.moe_fwd(layer_p["moe"], cfg, h2, num_groups)
                    x2 = x2 + mo
                else:
                    x2 = x2 + blocks.mlp_fwd(layer_p["mlp"], cfg, h2)
                kc = jnp.pad(k.astype(jnp.bfloat16),
                             ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v.astype(jnp.bfloat16),
                             ((0, 0), (0, pad), (0, 0), (0, 0)))
                return x2, (kc, vc)

            if kind == "shared_attn":
                ks, vs = [], []
                for _ in range(count):
                    x, (kc, vc) = body(x, params["shared"])
                    ks.append(kc[None])
                    vs.append(vc[None])
                new_segments.append({"k": jnp.concatenate(ks, 0),
                                     "v": jnp.concatenate(vs, 0)})
            else:
                x, (ks, vs) = jax.lax.scan(body, x, seg["layers"])
                new_segments.append({"k": ks, "v": vs})
        elif kind in ("mamba2", "mlstm", "slstm"):
            def body(x_, layer_p, _kind=kind):
                h = blocks.apply_norm(layer_p["ln"], cfg, x_)
                fwd = {"mamba2": ssm.mamba2_fwd, "mlstm": ssm.mlstm_fwd,
                       "slstm": ssm.slstm_fwd}[_kind]
                # chunked-parallel pass that ALSO emits the final recurrent
                # state (prefill = parallel form + state handoff to decode)
                out, st = fwd(layer_p["mixer"], cfg, h, return_state=True)
                return x_ + out, st

            x, sts = jax.lax.scan(body, x, seg["layers"])
            new_segments.append(sts)
        elif kind == "fftconv_mlp":
            def body(x_, inp):
                layer_p, _ = inp
                h = blocks.apply_norm(layer_p["ln1"], cfg, x_)
                vg = h @ layer_p["mix"]["w_in"].astype(h.dtype)
                v, _ = jnp.split(vg, 2, axis=-1)
                x2, _, _ = _block_fwd(cfg, "fftconv_mlp", layer_p, x_,
                                      positions, None, num_groups)
                hist = jnp.pad(v.astype(jnp.bfloat16),
                               ((0, 0), (0, pad), (0, 0)))
                return x2, hist
            x, hists = jax.lax.scan(
                body, x, (seg["layers"], jnp.zeros((count,))))
            new_segments.append({"v_hist": hists})
        else:
            raise ValueError(kind)

    x = blocks.apply_norm(params["final_norm"], cfg, x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if last_index is None:
        x_last = x[:, -1:, :]
        cache_len = jnp.full((bsz,), s, jnp.int32)
    else:
        x_last = jnp.take_along_axis(
            x, last_index[:, None, None].astype(jnp.int32), axis=1)
        cache_len = last_index.astype(jnp.int32) + 1
    logits = (x_last @ head.astype(dt)).astype(jnp.float32)
    logits = _mask_pad_vocab(cfg, logits)
    cache = {"len": cache_len, "segments": new_segments}
    return logits, cache


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Stacked per-segment decode state."""
    kv, hd = cfg.num_kv_heads, cfg.hd
    cache: Dict[str, Any] = {"len": jnp.zeros((batch,), jnp.int32),
                             "segments": []}

    def stack(tree, count):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), tree)

    for kind, count in cfg.resolved_segments():
        if kind in ("attn_mlp", "attn_moe", "shared_attn"):
            seg = {"k": jnp.zeros((count, batch, max_len, kv, hd), jnp.bfloat16),
                   "v": jnp.zeros((count, batch, max_len, kv, hd), jnp.bfloat16)}
        elif kind == "mamba2":
            seg = stack(ssm.mamba2_init_state(cfg, batch), count)
        elif kind == "mlstm":
            seg = stack(ssm.mlstm_init_state(cfg, batch), count)
        elif kind == "slstm":
            seg = stack(ssm.slstm_init_state(cfg, batch), count)
        elif kind == "fftconv_mlp":
            seg = {"v_hist": jnp.zeros((count, batch, max_len, cfg.d_model),
                                       jnp.bfloat16)}
        else:
            seg = {}
        cache["segments"].append(seg)
    return cache


def decode_step(params: Dict, cfg: ArchConfig, cache: Dict[str, Any],
                batch: Dict[str, jax.Array],
                num_groups: int = 1) -> Tuple[jax.Array, Dict[str, Any]]:
    """One new token per sequence. batch: {"tokens": (B,1)} or
    {"embeds": (B,1,d)}; returns (logits (B,1,V), new cache)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if "embeds" in batch:
        x = batch["embeds"].astype(dt)
        bsz = x.shape[0]
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
        bsz = batch["tokens"].shape[0]
    positions = cache["len"][:, None]                           # (B, 1)
    if cfg.rope == "none":
        x = x + _sinusoidal(positions, cfg.d_model).astype(x.dtype)

    new_segments = []
    for seg_p, seg_c, seg_cfg in zip(params["segments"], cache["segments"],
                                     cfg.resolved_segments()):
        kind, count = seg_cfg
        if kind in ("attn_mlp", "attn_moe", "shared_attn"):
            layer_cache = {"k": seg_c["k"], "v": seg_c["v"], "len": cache["len"]}
            pstack = params["shared"] if kind == "shared_attn" else seg_p["layers"]
            if kind == "shared_attn":
                # one occurrence per segment entry; params shared
                lc = {"k": seg_c["k"][0], "v": seg_c["v"][0], "len": cache["len"]}
                x, nc, _ = _block_fwd(cfg, kind, pstack, x, positions, lc,
                                      num_groups)
                new_segments.append({"k": nc["k"][None], "v": nc["v"][None]})
                continue

            def body(x_, inp, _kind=kind):
                layer_p, kc, vc = inp
                lc = {"k": kc, "v": vc, "len": cache["len"]}
                x2, nc, _ = _block_fwd(cfg, _kind, layer_p, x_, positions,
                                       lc, num_groups)
                return x2, (nc["k"], nc["v"])
            x, (ks, vs) = jax.lax.scan(
                body, x, (pstack, seg_c["k"], seg_c["v"]),
                unroll=_scan_unroll())
            new_segments.append({"k": ks, "v": vs})
        elif kind in ("mamba2", "mlstm", "slstm"):
            def body(x_, inp, _kind=kind):
                layer_p, st = inp
                x2, ns, _ = _block_fwd(cfg, _kind, layer_p, x_, positions,
                                       st, num_groups)
                return x2, ns
            x, ns = jax.lax.scan(body, x, (seg_p["layers"], seg_c),
                                 unroll=_scan_unroll())
            new_segments.append(ns)
        elif kind == "fftconv_mlp":
            def body(x_, inp):
                layer_p, hist = inp
                h = blocks.apply_norm(layer_p["ln1"], cfg, x_)
                mix, nh = blocks.fftconv_decode(layer_p["mix"], cfg, h, hist,
                                                cache["len"])
                x2 = x_ + mix
                h2 = blocks.apply_norm(layer_p["ln2"], cfg, x2)
                return x2 + blocks.mlp_fwd(layer_p["mlp"], cfg, h2), nh
            x, nh = jax.lax.scan(body, x, (seg_p["layers"], seg_c["v_hist"]),
                                 unroll=_scan_unroll())
            new_segments.append({"v_hist": nh})
        else:
            raise ValueError(f"decode unsupported for segment kind {kind!r}")

    x = blocks.apply_norm(params["final_norm"], cfg, x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(dt)).astype(jnp.float32)
    logits = _mask_pad_vocab(cfg, logits)
    new_cache = {"len": cache["len"] + 1, "segments": new_segments}
    return logits, new_cache
