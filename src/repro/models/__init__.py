from . import blocks, frontend, lm, ssm
from .config import (ArchConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME,
                     TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
from .lm import decode_step, forward, init_cache, init_params, loss_fn, model_meta
from .params import (ParamMeta, abstract_tree, init_tree, param_count,
                     pspec_tree, shard_act, sharding_rules)

__all__ = [
    "blocks", "frontend", "lm", "ssm",
    "ArchConfig", "ShapeConfig", "SHAPES", "SHAPES_BY_NAME",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "model_meta", "init_params", "forward", "loss_fn", "init_cache",
    "decode_step",
    "ParamMeta", "init_tree", "abstract_tree", "pspec_tree", "param_count",
    "shard_act", "sharding_rules",
]
