"""Modality frontend STUBS (per assignment: [vlm]/[audio] entries specify the
transformer backbone only; input_specs() provides precomputed frame/patch
embeddings).

A real deployment would run a ViT patch encoder (qwen2-vl) or EnCodec
quantizer (musicgen) here — the latter being itself an STFT consumer of the
repro.core FFT stack.  For this framework the frontend contract is just the
embedding tensor contract below, plus M-RoPE position streams for vision.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig


def synth_embeddings(cfg: ArchConfig, batch: int, seq: int,
                     key: jax.Array) -> jax.Array:
    """Stand-in for frontend output: (B, S, d) embeddings."""
    return jax.random.normal(key, (batch, seq, cfg.d_model),
                             jnp.float32).astype(jnp.dtype(cfg.compute_dtype)) * 0.02


def mrope_positions(batch: int, seq: int, grid_hw: int = 16) -> jax.Array:
    """(3, B, S) temporal/height/width position streams for M-RoPE.

    Synthetic layout: a leading image of grid_hw x grid_hw patches followed
    by text tokens (qwen2-vl dynamic-resolution order, fixed here)."""
    n_img = min(grid_hw * grid_hw, seq)
    t = np.zeros(seq, np.int32)
    h = np.zeros(seq, np.int32)
    w = np.zeros(seq, np.int32)
    h[:n_img] = np.arange(n_img) // grid_hw
    w[:n_img] = np.arange(n_img) % grid_hw
    text_pos = np.arange(seq - n_img) + (n_img // grid_hw)
    t[n_img:] = text_pos
    h[n_img:] = text_pos
    w[n_img:] = text_pos
    pos = np.stack([t, h, w])                                   # (3, S)
    return jnp.asarray(np.broadcast_to(pos[:, None], (3, batch, seq)))
