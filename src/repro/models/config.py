"""Architecture configuration schema for the assigned-architecture pool."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # block layout: sequence of (kind, count) segments executed in order.
    # kinds: "attn_mlp" | "attn_moe" | "mlstm" | "slstm" | "mamba2" |
    #        "shared_attn" (single shared param set) | "fftconv_mlp"
    segments: Tuple[Tuple[str, int], ...] = ()

    # attention
    head_dim: Optional[int] = None    # default d_model // num_heads
    rope: str = "standard"            # standard | mrope | none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    qkv_bias: bool = False
    parallel_block: bool = False      # command-r style parallel attn+FFN
    logit_softcap: float = 0.0

    # norm / misc
    norm: str = "rmsnorm"             # rmsnorm | layernorm | nonparam_ln
    tie_embeddings: bool = False
    mlp_act: str = "silu"             # silu (SwiGLU) | gelu (plain)

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / recurrent
    ssm_state: int = 0                # Mamba2 N
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    slstm_heads: int = 4

    # fftconv mixer (paper-technique ablation)
    fftconv_rank: int = 16

    # modality frontend stub ("vision" | "audio" | None): inputs are
    # precomputed embeddings, not token ids
    frontend: Optional[str] = None

    # whether full attention makes long_500k infeasible (quadratic): decides
    # the documented skip for the long-context cell
    subquadratic: bool = False

    # training details
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    # "full" = nothing_saveable (max recompute, min live memory);
    # "dots" = dots_with_no_batch_dims_saveable (keep matmul outputs,
    # recompute elementwise only — trades HBM residency for ~25% less
    # recompute flops; the granite train §Perf iteration)
    remat_policy: str = "full"
    # dtype of TP partial-sum reductions on out-projections (None = XLA
    # default, which all-reduces the f32 accumulator).  Serving sets
    # "bfloat16": halves cross-chip reduction bytes (§Perf hillclimb).
    reduce_dtype: str | None = None

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def resolved_segments(self) -> Tuple[Tuple[str, int], ...]:
        if self.segments:
            return self.segments
        kind = "attn_moe" if self.num_experts else "attn_mlp"
        return ((kind, self.num_layers),)

    def total_layers(self) -> int:
        return sum(n for _, n in self.resolved_segments())


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}
