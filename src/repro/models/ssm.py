"""Recurrent sequence mixers: Mamba2 (SSD), xLSTM (mLSTM + sLSTM).

All linear-recurrent mixers share one chunked-parallel core:

    S_t = a_t * S_{t-1} + k_t v_t^T          (state: dk x dv per head)
    y_t = q_t . S_t

computed per chunk with pairwise-decay einsums (matmul-structured, MXU
friendly) and a lax.scan carrying the inter-chunk state — O(L) memory, O(1)
decode.  Mamba2 folds dt into v and uses (C, B) as (q, k); mLSTM folds the
exponential input gate into k and appends a normalizer column to v.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .params import ParamMeta, shard_act

# ---------------------------------------------------------------------------
# shared chunked gated linear attention
# ---------------------------------------------------------------------------


def chunked_gla(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array,
                state: Optional[jax.Array] = None, chunk: int = 128
                ) -> Tuple[jax.Array, jax.Array]:
    """q,k (B,L,H,dk); v (B,L,H,dv); log_a (B,L,H) log-decay (<= 0).

    Returns y (B,L,H,dv) and final state (B,H,dk,dv).
    """
    b, l, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, l)
    while l % chunk:
        chunk -= 1
    nc = l // chunk

    qc = jnp.moveaxis(q.reshape(b, nc, chunk, h, dk), 1, 0).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(b, nc, chunk, h, dk), 1, 0).astype(jnp.float32)
    vc = jnp.moveaxis(v.reshape(b, nc, chunk, h, dv), 1, 0).astype(jnp.float32)
    lac = jnp.moveaxis(log_a.reshape(b, nc, chunk, h), 1, 0).astype(jnp.float32)

    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]                       # (Q, Q)

    def step(s, inp):
        qi, ki, vi, la = inp                                    # (B,Q,H,*)
        cl = jnp.cumsum(la, axis=1)                             # inclusive
        # intra-chunk: pairwise decay exp(cl_i - cl_j), causal
        dec = cl[:, :, None, :] - cl[:, None, :, :]             # (B,Q,Q,H)
        dec = jnp.where(causal[None, :, :, None], dec, -jnp.inf)
        att = jnp.einsum("bihd,bjhd->bijh", qi, ki) * jnp.exp(dec)
        y = jnp.einsum("bijh,bjhv->bihv", att, vi)
        # carry-in: q_i . S_prev decayed by exp(cl_i)
        y = y + jnp.einsum("bihd,bhdv->bihv", qi * jnp.exp(cl)[..., None], s)
        # state update: S' = exp(cl_last) S + sum_j exp(cl_last - cl_j) k_j v_j
        w = jnp.exp(cl[:, -1:, :] - cl)                         # (B,Q,H)
        s_new = s * jnp.exp(cl[:, -1])[:, :, None, None]        # (B,H,1,1)
        s_new = s_new + jnp.einsum("bjhd,bjh,bjhv->bhdv", ki, w, vi)
        return s_new, y

    state, ys = jax.lax.scan(step, state, (qc, kc, vc, lac))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, h, dv)
    return y, state


def gla_decode_step(q: jax.Array, k: jax.Array, v: jax.Array, a: jax.Array,
                    state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token state update. q,k (B,H,dk); v (B,H,dv); a (B,H) decay."""
    state = state * a[..., None, None] + jnp.einsum("bhd,bhv->bhdv", k, v)
    y = jnp.einsum("bhd,bhdv->bhv", q, state)
    return y, state


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba2_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    di = cfg.ssm_expand * cfg.d_model
    nheads = di // cfg.ssm_head_dim
    return di, nheads, cfg.ssm_state


def mamba2_meta(cfg: ArchConfig) -> Dict[str, ParamMeta]:
    d = cfg.d_model
    di, nh, n = mamba2_dims(cfg)
    kc = cfg.conv_kernel
    return {
        "in_proj": ParamMeta((d, 2 * di + 2 * n + nh), ("fsdp", "tp")),
        "conv_w": ParamMeta((di + 2 * n, kc), ("tp", None), scale=0.5),
        "a_log": ParamMeta((nh,), ("tp",), init="zeros"),
        "dt_bias": ParamMeta((nh,), ("tp",), init="zeros"),
        "d_skip": ParamMeta((nh,), ("tp",), init="ones"),
        "norm": ParamMeta((di,), (None,), init="ones"),
        "out_proj": ParamMeta((di, d), ("tp", "fsdp")),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along axis 1. x (B, L, C); w (C, K)."""
    k = w.shape[-1]
    if state is not None:                                       # decode: L == 1
        window = jnp.concatenate([state, x], axis=1)            # (B, K, C)
        y = jnp.einsum("bkc,ck->bc", window, w)[:, None, :]
        return y, window[:, 1:]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # windows: y_t = sum_i x_{t-K+1+i} * w[:, i]
    y = jnp.zeros_like(x)
    for i in range(k):
        y = y + pad[:, i:i + x.shape[1], :] * w.T[None, i, :][None]
    new_state = pad[:, -(k - 1):, :]
    return y, new_state


def mamba2_fwd(p: Dict, cfg: ArchConfig, x: jax.Array,
               state: Optional[Dict] = None, chunk: int = 128,
               return_state: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """x (B, L, d). state: {"conv": (B,K-1,C), "ssd": (B,H,N,P)} for decode."""
    b, l, d = x.shape
    di, nh, n = mamba2_dims(cfg)
    hd = cfg.ssm_head_dim
    dt_ = x.dtype

    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xin, bc, dt_pre = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * n], -1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)               # (B,L,di+2n)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(
        conv_in.astype(jnp.float32), p["conv_w"].astype(jnp.float32), conv_state)
    conv_out = jax.nn.silu(conv_out)
    xc, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_pre.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (B,L,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                # (H,)
    log_decay = a[None, None, :] * dt                           # (B,L,H) <= 0

    xh = xc.reshape(b, l, nh, hd)
    v = xh * dt[..., None]                                      # fold dt
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, l, nh, n))    # shared B
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, l, nh, n))

    if state is None:
        y, ssd_state = chunked_gla(q, k, v, log_decay, chunk=chunk)
        new_state = ({"conv": new_conv, "ssd": ssd_state}
                     if return_state else None)
    else:
        yq, ssd_state = gla_decode_step(
            q[:, 0], k[:, 0], v[:, 0], jnp.exp(log_decay[:, 0]), state["ssd"])
        y = yq[:, None]
        new_state = {"conv": new_conv, "ssd": ssd_state}

    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, l, di)
    # gated RMSNorm (Mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm"].astype(jnp.float32)
    out = (y.astype(dt_) @ p["out_proj"].astype(dt_))
    if state is None and not return_state:
        return shard_act(out, "dp", None, None), None
    return out, new_state


def mamba2_init_state(cfg: ArchConfig, batch: int) -> Dict:
    di, nh, n = mamba2_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, di + 2 * n), jnp.float32),
        "ssd": jnp.zeros((batch, nh, n, cfg.ssm_head_dim), jnp.float32),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory)
# ---------------------------------------------------------------------------


def mlstm_meta(cfg: ArchConfig) -> Dict[str, ParamMeta]:
    """xLSTM mLSTM block, projection factor 2.  q/k are PER-HEAD (block-
    diagonal) projections of the up-projected branch and v IS that branch —
    this is what keeps xlstm-1.3b at ~1.3B params (dense du x du qkv would
    triple it)."""
    d = cfg.d_model
    du = 2 * d                                                  # proj factor 2
    h = cfg.num_heads
    dh = du // h
    return {
        "w_up": ParamMeta((d, du), ("fsdp", "tp")),
        "w_gate": ParamMeta((d, du), ("fsdp", "tp")),
        "wq": ParamMeta((h, dh, dh), ("tp", None, None)),
        "wk": ParamMeta((h, dh, dh), ("tp", None, None)),
        "wi": ParamMeta((d, h), ("fsdp", "tp"), scale=0.01),
        "wf": ParamMeta((d, h), ("fsdp", "tp"), scale=0.01),
        "bi": ParamMeta((h,), ("tp",), init="zeros"),
        "bf": ParamMeta((h,), ("tp",), init="ones", scale=3.0),
        "norm": ParamMeta((du,), (None,), init="ones"),
        "w_down": ParamMeta((du, d), ("tp", "fsdp")),
    }


def mlstm_fwd(p: Dict, cfg: ArchConfig, x: jax.Array,
              state: Optional[Dict] = None, chunk: int = 128,
              return_state: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """Chunked-parallel mLSTM: exponential input gate folded into k, sigmoid
    forget gate as the decay, normalizer as an extra value column."""
    b, l, d = x.shape
    h = cfg.num_heads
    du = 2 * d
    dh = du // h
    dt_ = x.dtype

    u = x @ p["w_up"].astype(dt_)
    gate = x @ p["w_gate"].astype(dt_)
    ur = u.reshape(b, l, h, dh)
    q = jnp.einsum("bshd,hde->bshe", ur, p["wq"].astype(dt_)) / math.sqrt(dh)
    k = jnp.einsum("bshd,hde->bshe", ur, p["wk"].astype(dt_))
    v = ur

    xf = x.astype(jnp.float32)
    ig = xf @ p["wi"].astype(jnp.float32) + p["bi"].astype(jnp.float32)
    fg = xf @ p["wf"].astype(jnp.float32) + p["bf"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(fg)                              # (B,L,H)
    i_gate = jnp.exp(jnp.minimum(ig, 8.0))                      # bounded exp gate

    kf = k.astype(jnp.float32) * i_gate[..., None]
    v_aug = jnp.concatenate(
        [v.astype(jnp.float32), jnp.ones((b, l, h, 1), jnp.float32)], -1)

    if state is None:
        y_aug, s_new = chunked_gla(q.astype(jnp.float32), kf, v_aug, log_f,
                                   chunk=chunk)
        new_state = {"mlstm": s_new} if return_state else None
    else:
        y1, s_new = gla_decode_step(q[:, 0].astype(jnp.float32), kf[:, 0],
                                    v_aug[:, 0], jnp.exp(log_f[:, 0]),
                                    state["mlstm"])
        y_aug = y1[:, None]
        new_state = {"mlstm": s_new}

    y_num, y_den = y_aug[..., :dh], y_aug[..., dh:]
    y = y_num / jnp.maximum(jnp.abs(y_den), 1.0)
    y = y.reshape(b, l, du)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm"].astype(jnp.float32)
    y = y.astype(dt_) * jax.nn.silu(gate)
    out = y @ p["w_down"].astype(dt_)
    if state is None and not return_state:
        return shard_act(out, "dp", None, None), None
    return out, new_state


def mlstm_init_state(cfg: ArchConfig, batch: int) -> Dict:
    h = cfg.num_heads
    dh = 2 * cfg.d_model // h
    return {"mlstm": jnp.zeros((batch, h, dh, dh + 1), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar memory, true recurrence)
# ---------------------------------------------------------------------------


def slstm_meta(cfg: ArchConfig) -> Dict[str, ParamMeta]:
    d = cfg.d_model
    h = cfg.slstm_heads
    dh = d // h
    return {
        "w_gates": ParamMeta((d, 4, h, dh), ("fsdp", None, "tp", None)),
        "r_gates": ParamMeta((4, h, dh, dh), (None, "tp", None, None),
                             scale=0.01),
        "b_gates": ParamMeta((4, h, dh), (None, "tp", None), init="zeros"),
        "w_out": ParamMeta((d, d), ("fsdp", "tp")),
    }


def _slstm_cell(p, wx_t, carry):
    """wx_t (B,4,H,dh) precomputed input contributions; carry (c,n,h,m)."""
    c, n, hprev, m = carry
    rec = jnp.einsum("bhd,ghde->bghe", hprev, p["r_gates"].astype(jnp.float32))
    pre = wx_t + rec + p["b_gates"].astype(jnp.float32)[None]
    zt = jnp.tanh(pre[:, 0])
    it = pre[:, 1]
    ft = pre[:, 2]
    ot = jax.nn.sigmoid(pre[:, 3])
    # stabilized exponential gating (xLSTM eq. 15-17)
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * zt
    n_new = f_p * n + i_p
    h_new = ot * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_fwd(p: Dict, cfg: ArchConfig, x: jax.Array,
              state: Optional[Dict] = None,
              return_state: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    b, l, d = x.shape
    h = cfg.slstm_heads
    dh = d // h
    dt_ = x.dtype
    wx = jnp.einsum("bsd,dghe->bsghe", x.astype(jnp.float32),
                    p["w_gates"].astype(jnp.float32))           # (B,L,4,H,dh)

    if state is None:
        zero = jnp.zeros((b, h, dh), jnp.float32)
        carry0 = (zero, zero, zero, jnp.full((b, h, dh), -1e30, jnp.float32))
    else:
        carry0 = state["slstm"]

    def step(carry, wx_t):
        new = _slstm_cell(p, wx_t, carry)
        return new, new[2]

    carry, hs = jax.lax.scan(step, carry0, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, l, d)
    out = y.astype(dt_) @ p["w_out"].astype(dt_)
    if state is None and not return_state:
        return shard_act(out, "dp", None, None), None
    return out, {"slstm": carry}


def slstm_init_state(cfg: ArchConfig, batch: int) -> Dict:
    h = cfg.slstm_heads
    dh = cfg.d_model // h
    zero = jnp.zeros((batch, h, dh), jnp.float32)
    return {"slstm": (zero, zero, zero,
                      jnp.full((batch, h, dh), -1e30, jnp.float32))}
