"""Parameter metadata: one tree declares shape, init, and logical sharding.

Logical axes ("fsdp", "tp", "expert", None per dim) are mapped to physical
mesh axes by a rule table at launch time, so the same model definition runs
on the single-pod (data, model) mesh, the multi-pod (pod, data, model) mesh,
or a single CPU device (rules = {}).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"              # normal | zeros | ones
    scale: float = 0.02
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def _map_tree(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_meta)


def init_tree(meta_tree, key: jax.Array):
    """Materialize a parameter tree from metadata."""
    leaves, treedef = jax.tree_util.tree_flatten(meta_tree, is_leaf=is_meta)
    keys = jax.random.split(key, max(len(leaves), 1))

    def make(meta: ParamMeta, k):
        if meta.init == "zeros":
            return jnp.zeros(meta.shape, meta.dtype)
        if meta.init == "ones":
            return jnp.ones(meta.shape, meta.dtype)
        return (jax.random.normal(k, meta.shape, jnp.float32)
                * meta.scale).astype(meta.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [make(m, k) for m, k in zip(leaves, keys)])


def abstract_tree(meta_tree):
    """ShapeDtypeStruct tree (for dry-runs: no allocation)."""
    return _map_tree(lambda m: jax.ShapeDtypeStruct(m.shape, m.dtype), meta_tree)


def pspec_tree(meta_tree, rules: Dict[str, Any]):
    """PartitionSpec tree via logical->physical axis rules.

    rules example: {"fsdp": ("pod", "data"), "tp": "model", "expert": "model"}
    Logical names missing from the table are replicated.
    """
    def spec(meta: ParamMeta):
        return P(*[rules.get(ax) if ax is not None else None
                   for ax in meta.logical])
    return _map_tree(spec, meta_tree)


def param_count(meta_tree) -> int:
    leaves = jax.tree_util.tree_leaves(meta_tree, is_leaf=is_meta)
    total = 0
    for m in leaves:
        c = 1
        for s in m.shape:
            c *= s
        total += c
    return total


# ---------------------------------------------------------------------------
# ambient mesh + rules for activation sharding constraints
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def sharding_rules(mesh: Optional[jax.sharding.Mesh], rules: Dict[str, Any]):
    prev = getattr(_CTX, "val", None)
    _CTX.val = (mesh, rules)
    try:
        yield
    finally:
        _CTX.val = prev


def shard_act(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain activation sharding by logical axes; no-op without a mesh."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None or ctx[0] is None:
        return x
    mesh, rules = ctx
    spec = P(*[rules.get(ax) if ax is not None else None for ax in logical])
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def current_rules() -> Dict[str, Any]:
    ctx = getattr(_CTX, "val", None)
    return ctx[1] if ctx else {}


def current_mesh() -> Optional[jax.sharding.Mesh]:
    ctx = getattr(_CTX, "val", None)
    return ctx[0] if ctx else None
