"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention (flash-
style chunked for long prefill), SwiGLU MLP, capacity-based MoE with expert
parallelism, and the FFT-convolution mixer (the paper's technique as a
sequence mixer)."""

from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.compat import scan_carry

from .config import ArchConfig
from .params import ParamMeta, shard_act


def _reduce_pe(cfg: ArchConfig):
    """preferred_element_type for TP-psum-carrying out-projections: the
    cross-chip all-reduce happens in this dtype.  Train keeps f32 partial
    sums (explicit — jnp.einsum would otherwise emit an f32 accumulator
    anyway); serving opts into bf16, halving reduction wire bytes."""
    return jnp.dtype(cfg.reduce_dtype) if cfg.reduce_dtype else jnp.float32

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_meta(cfg: ArchConfig) -> Dict[str, ParamMeta]:
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": ParamMeta((d,), (None,), init="ones")}
    if cfg.norm == "layernorm":
        return {"scale": ParamMeta((d,), (None,), init="ones"),
                "bias": ParamMeta((d,), (None,), init="zeros")}
    return {}  # nonparam_ln (olmo): no learnable parameters


def apply_norm(p: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
        if cfg.norm == "layernorm":
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def _rope_angles(positions: jax.Array, hd: int, theta: float = 1e4) -> Tuple:
    """positions (..., S) -> cos/sin (..., S, hd//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def _mrope_angles(positions3: jax.Array, hd: int,
                  sections: Tuple[int, ...], theta: float = 1e4) -> Tuple:
    """M-RoPE (qwen2-vl): positions3 (3, B, S); per-section angle source.

    sections give the number of frequency slots (out of hd//2) driven by the
    temporal / height / width position streams respectively.
    """
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions3[..., None].astype(jnp.float32) * inv      # (3, B, S, hd/2)
    idx = []
    for sec_id, n in enumerate(sections):
        idx += [sec_id] * n
    sel = jnp.asarray(np.array(idx, np.int32))                 # (hd/2,)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -1), sel[None, None, :, None], axis=-1)[..., 0]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, hd); cos/sin (B, S, hd//2)."""
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def rope_tables(cfg: ArchConfig, positions: jax.Array):
    if cfg.rope == "none":
        return None
    if cfg.rope == "mrope":
        if positions.ndim == 2:                                 # text-only: t=h=w
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return _mrope_angles(positions, cfg.hd, cfg.mrope_sections)
    return _rope_angles(positions, cfg.hd)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def attention_meta(cfg: ArchConfig) -> Dict[str, ParamMeta]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    m = {
        "wq": ParamMeta((d, h, hd), ("fsdp", "tp", None)),
        "wk": ParamMeta((d, kv, hd), ("fsdp", "tp", None)),
        "wv": ParamMeta((d, kv, hd), ("fsdp", "tp", None)),
        "wo": ParamMeta((h, hd, d), ("tp", None, "fsdp")),
    }
    if cfg.qkv_bias:
        m["bq"] = ParamMeta((h, hd), ("tp", None), init="zeros")
        m["bk"] = ParamMeta((kv, hd), ("tp", None), init="zeros")
        m["bv"] = ParamMeta((kv, hd), ("tp", None), init="zeros")
    return m


def _qkv(p: Dict, cfg: ArchConfig, x: jax.Array, rope) -> Tuple:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_kv: int = 1024,
                    q_offset: int = 0) -> jax.Array:
    """Chunked online-softmax attention (pure JAX flash).

    q (B, Sq, H, hd); k/v (B, Sk, KV, hd) with H = KV * G.  Memory is
    O(Sq * block_kv) instead of O(Sq * Sk) — required for 32k prefill.
    """
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32) * scale

    block_kv = min(block_kv, sk)
    while sk % block_kv:
        block_kv -= 1
    nkv = sk // block_kv
    kb = jnp.moveaxis(k.reshape(b, nkv, block_kv, kvh, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nkv, block_kv, kvh, hd), 1, 0)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        acc, m_run, l_run = carry
        kj, vj, j = inp
        s = jnp.einsum("bqkgd,bskd->bqkgs", qr.astype(kj.dtype), kj,
                       preferred_element_type=jnp.float32)
        if causal:
            kv_pos = j * block_kv + jnp.arange(block_kv)
            mask = q_pos[:, None] >= kv_pos[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        pexp = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgs,bskd->bqkgd", pexp.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        l_run = l_run * corr + jnp.sum(pexp, axis=-1)
        return (acc, m_new, l_run), None

    acc0 = jnp.zeros((b, sq, kvh, g, hd), jnp.float32)
    m0 = jnp.full((b, sq, kvh, g), -1e30, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    # scan_carry: plain lax.scan on modern JAX; unrolled on JAX 0.4.x so the
    # kv loop survives inside partial-manual shard_map regions (GPipe stages)
    (acc, _, l_run), _ = scan_carry(
        step, (acc0, m0, l0), (kb, vb, jnp.arange(nkv)))
    out = acc / jnp.maximum(l_run[..., None], 1e-30)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array) -> jax.Array:
    """Single-token attention against a (B, S, KV, hd) cache."""
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k_cache.shape
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qr = (q.reshape(b, sq, kvh, g, hd) * scale).astype(k_cache.dtype)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qr, k_cache,
                   preferred_element_type=jnp.float32)
    mask = jnp.arange(sk)[None, :] < cache_len[:, None]         # (B, S)
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def attention_fwd(p: Dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
                  cache: Optional[Dict] = None) -> Tuple[jax.Array, Optional[Dict]]:
    """Returns (output, updated_cache). cache=None -> causal self-attention."""
    rope = rope_tables(cfg, positions)
    q, k, v = _qkv(p, cfg, x, rope)
    q = shard_act(q, "dp", None, "tp", None)
    if cache is None:
        out = flash_attention(q, k, v, causal=True)
    else:
        idx = cache["len"]                                      # (B,) int32
        kc = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u.astype(c.dtype), (i, 0, 0)))(cache["k"], k, idx)
        vc = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u.astype(c.dtype), (i, 0, 0)))(cache["v"], v, idx)
        out = decode_attention(q, kc, vc, idx + 1)
        cache = {"k": kc, "v": vc, "len": idx + 1}
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype),
                   preferred_element_type=_reduce_pe(cfg))
    return shard_act(y.astype(x.dtype), "dp", None, None), cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_meta(cfg: ArchConfig) -> Dict[str, ParamMeta]:
    d, f = cfg.d_model, cfg.d_ff
    m = {"w_up": ParamMeta((d, f), ("fsdp", "tp")),
         "w_down": ParamMeta((f, d), ("tp", "fsdp"))}
    if cfg.mlp_act == "silu":
        m["w_gate"] = ParamMeta((d, f), ("fsdp", "tp"))
    return m


def mlp_fwd(p: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    dt = x.dtype
    up = x @ p["w_up"].astype(dt)
    if cfg.mlp_act == "silu":
        up = jax.nn.silu(x @ p["w_gate"].astype(dt)) * up
    else:
        up = jax.nn.gelu(up)
    up = shard_act(up, "dp", None, "tp")
    down = jax.lax.dot_general(up, p["w_down"].astype(dt),
                               (((up.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=_reduce_pe(cfg))
    return shard_act(down.astype(dt), "dp", None, None)


# ---------------------------------------------------------------------------
# MoE: top-k routing, capacity dispatch, expert parallelism over "expert"
# ---------------------------------------------------------------------------


def moe_meta(cfg: ArchConfig) -> Dict[str, ParamMeta]:
    """Expert weights use the 'moe_d'/'moe_f' logical axes so the rule table
    can switch between training layout (d FSDP-sharded, gathered on use) and
    weight-stationary serving layout (d_ff sharded over the data axis; the
    contraction psums activations instead of all-gathering 3*d*ff*E weight
    bytes per layer — the dbrx prefill hillclimb, EXPERIMENTS.md §Perf)."""
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamMeta((d, e), (None, None), scale=0.02 / math.sqrt(d)),
        "w_up": ParamMeta((e, d, f), ("expert", "moe_d", "moe_f")),
        "w_gate": ParamMeta((e, d, f), ("expert", "moe_d", "moe_f")),
        "w_down": ParamMeta((e, f, d), ("expert", "moe_f", "moe_d")),
    }


def _group_dispatch(xg: jax.Array, eid: jax.Array, pos: jax.Array,
                    keep: jax.Array, e: int, cap: int) -> jax.Array:
    """Scatter one group's tokens into (E, cap, d) expert buffers."""
    d = xg.shape[-1]
    tk = eid.shape[0]
    buf = jnp.zeros((e, cap, d), xg.dtype)
    safe_pos = jnp.where(keep, pos, cap - 1)
    upd = xg * keep[:, None].astype(xg.dtype)
    return buf.at[eid, safe_pos].add(upd, mode="drop")


def moe_fwd(p: Dict, cfg: ArchConfig, x: jax.Array,
            num_groups: int = 1) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out, aux_loss). GShard-style grouped dispatch:

    tokens are grouped by data shard; dispatch buffers are laid out
    (G, E, C, d) and resharded to (E, G, C, d) — GSPMD lowers that logical
    transpose to the all_to_all the paper's communication step prescribes.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    g = num_groups
    while t % g:
        g -= 1
    tg = t // g
    cap = max(int(cfg.capacity_factor * tg * k / e), 4)
    cap = min(cap, tg * k)

    xt = x.reshape(g, tg, d)
    xt = shard_act(xt, "dp", None, None)
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                     # (G, Tg, E)
    topv, topi = jax.lax.top_k(gates, k)                        # (G, Tg, K)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(gates, axis=1)                                # (G, E)
    ce = jnp.mean(jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32), axis=1)
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))

    # position of each (token, choice) within its expert's buffer
    oh = jax.nn.one_hot(topi, e, dtype=jnp.int32)               # (G, Tg, K, E)
    flat = oh.reshape(g, tg * k, e)
    pos_all = jnp.cumsum(flat, axis=1) - flat
    pos = jnp.sum(pos_all * flat, axis=-1)                      # (G, Tg*K)
    eid = topi.reshape(g, tg * k)
    keep = pos < cap

    xrep = jnp.repeat(xt, k, axis=1)                            # (G, Tg*K, d)
    buf = jax.vmap(functools.partial(_group_dispatch, e=e, cap=cap))(
        xrep, eid, pos, keep)                                   # (G, E, C, d)
    buf = shard_act(buf, "dp", "expert", None, None)

    # expert-major layout: GSPMD inserts the all_to_all here
    ebuf = shard_act(jnp.swapaxes(buf, 0, 1), "expert", "dp", None, None)
    dt = x.dtype
    h = jnp.einsum("egcd,edf->egcf", ebuf.astype(dt), p["w_up"].astype(dt))
    hg = jnp.einsum("egcd,edf->egcf", ebuf.astype(dt), p["w_gate"].astype(dt))
    h = jax.nn.silu(hg) * h
    h = shard_act(h, "expert", "dp", None, "moe_f")
    eout = jnp.einsum("egcf,efd->egcd", h, p["w_down"].astype(dt),
                      preferred_element_type=_reduce_pe(cfg)).astype(dt)
    eout = shard_act(eout, "expert", "dp", None, None)

    # back to group-major; the buffer is REPLICATED across the expert axis
    # before the combine gather: one bf16 all-gather instead of the masked
    # gather + f32 all-reduce GSPMD otherwise emits (4x the wire bytes) —
    # see EXPERIMENTS.md §Perf (dbrx prefill iteration 2)
    gbuf = shard_act(jnp.swapaxes(eout, 0, 1), "dp", None, None, None)

    def gather_group(gb, ei, ps, kp, tv):
        got = gb[ei, ps] * kp[:, None].astype(gb.dtype)         # (Tg*K, d)
        got = got.reshape(tg, k, d) * tv[..., None].astype(gb.dtype)
        return jnp.sum(got, axis=1)

    out = jax.vmap(gather_group)(gbuf, eid, jnp.where(keep, pos, 0),
                                 keep, topv)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# FFT-convolution mixer (paper technique in the LM stack)
# ---------------------------------------------------------------------------


def fftconv_meta(cfg: ArchConfig) -> Dict[str, ParamMeta]:
    d, r = cfg.d_model, cfg.fftconv_rank
    return {
        "w_in": ParamMeta((d, 2 * d), ("fsdp", "tp")),
        "filt": ParamMeta((d, r), (None, None), scale=0.2),
        "skip": ParamMeta((d,), (None,), init="ones"),
        "w_out": ParamMeta((d, d), ("tp", "fsdp")),
    }


def fftconv_fwd(p: Dict, cfg: ArchConfig, x: jax.Array,
                seq_axis_sharded: bool = False) -> jax.Array:
    """Gated long convolution: y = W_out( fftconv(v) * silu(g) ).

    Sub-quadratic sequence mixing powered by repro.core — the paper's FFT as
    a first-class model feature.  When the sequence is sharded, the
    distributed slab FFT (all_to_all collectives) is used.
    """
    from repro.core import fftconv as fc
    from repro.models.params import current_mesh, current_rules

    dt = x.dtype
    b, s, d = x.shape
    vg = x @ p["w_in"].astype(dt)
    v, gate = jnp.split(vg, 2, axis=-1)
    filt = fc.materialize_filter(p["filt"].astype(jnp.float32), s)
    mesh = current_mesh()
    if seq_axis_sharded and mesh is not None:
        rules = current_rules()
        axis = rules.get("sp", rules.get("dp"))
        if isinstance(axis, tuple):
            axis = axis[-1]
        y = fc.fft_conv_seq_sharded(v, filt, mesh, axis)
    else:
        y = fc.fft_conv(v, filt)
    y = y + v * p["skip"].astype(dt)
    y = y * jax.nn.silu(gate)
    return shard_act(y @ p["w_out"].astype(dt), "dp", None, None)


def fftconv_decode(p: Dict, cfg: ArchConfig, x: jax.Array, hist: jax.Array,
                   pos: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token long-conv step: y_t = sum_{j<=t} k[t-j] v_j over the cached
    value history. x (B,1,d); hist (B,S_max,d); pos (B,) current index."""
    from repro.core import fftconv as fc
    dt = x.dtype
    b, _, d = x.shape
    s_max = hist.shape[1]
    vg = x @ p["w_in"].astype(dt)
    v, gate = jnp.split(vg, 2, axis=-1)
    hist = jax.vmap(lambda h, u, i: jax.lax.dynamic_update_slice(
        h, u.astype(h.dtype), (i, 0)))(hist, v, pos)
    filt = fc.materialize_filter(p["filt"].astype(jnp.float32), s_max)  # (d,S)
    lag = pos[:, None] - jnp.arange(s_max)[None, :]             # (B, S)
    kk = jnp.take(filt, jnp.clip(lag, 0, s_max - 1), axis=1)    # (d, B, S)
    kk = jnp.where((lag >= 0)[None], kk, 0.0)
    y = jnp.einsum("bsd,dbs->bd", hist.astype(jnp.float32), kk)[:, None, :]
    y = y.astype(dt) + v * p["skip"].astype(dt)
    y = y * jax.nn.silu(gate)
    return y @ p["w_out"].astype(dt), hist
