"""Pipelined LM training step: GPipe over the pod axis for uniform-stack
dense architectures.

FSDP over (pod, data) all-gathers every weight across the DCN between pods
each layer; pipelining instead keeps weights POD-LOCAL (the layer stack's
leading dim is sharded over "pod") and sends only microbatch activations at
stage boundaries — cross-pod traffic drops from O(params) to
O(microbatches x mb x S x d) per step, plus ONE scalar (the loss).

shard_map is manual over {"pod"} only; "data"/"model" stay auto-sharded by
GSPMD inside the stage (FSDP+TP within a pod, PP across pods).  The loss is
computed inside the manual region on the last stage and psum-masked — no
activation broadcast across pods at all.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.compat import partial_manual_region, scan_carry, shard_map
from repro.models import blocks, lm
from repro.models.config import ArchConfig
from repro.models.params import shard_act, sharding_rules

from .pipeline import pipeline_stages


def supports_pipeline(cfg: ArchConfig) -> bool:
    segs = cfg.resolved_segments()
    return len(segs) == 1 and segs[0][0] in ("attn_mlp",)


def pipelined_loss_fn(params: Dict[str, Any], cfg: ArchConfig,
                      batch: Dict[str, jax.Array], mesh, rules: Dict,
                      num_microbatches: int = 8):
    """Cross-entropy loss with the layer stack executed as a pod-axis
    pipeline.  params["segments"][0]["layers"] leading dim is sharded P("pod")."""
    assert supports_pipeline(cfg), "pipeline supports uniform dense stacks"
    dt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    labels = batch["labels"]
    bsz, s = tokens.shape
    m = num_microbatches
    while bsz % m:
        m -= 1
    mb = bsz // m

    with sharding_rules(mesh, rules):
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        x = shard_act(x, "dp", None, None)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (mb, s))
        x_mb = x.reshape(m, mb, s, cfg.d_model)
        lab_mb = labels.reshape(m, mb, s)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(dt)

        def stage(layers_local, xin):
            def layer(x_, lp):
                x2, _, _ = lm._block_fwd(cfg, "attn_mlp", lp, x_,
                                         positions, None, 1)
                return x2, None
            body = layer
            if cfg.remat:
                body = jax.checkpoint(
                    layer, policy=jax.checkpoint_policies.nothing_saveable)
            out, _ = scan_carry(body, xin, layers_local)
            return out

        def pod_body(layers_stage, stage_id, xmb, labmb, norm_p, head_):
            # stage index arrives as DATA (iota sharded over "pod"):
            # axis_index cannot lower under partial-manual shard_map on
            # JAX 0.4.x (see repro.core.compat)
            outs, me, stages = pipeline_stages(stage, layers_stage, xmb,
                                               "pod", me=stage_id[0])
            # head + loss on the LAST stage only; psum the masked scalar
            y = blocks.apply_norm(norm_p, cfg, outs.reshape(bsz, s,
                                                            cfg.d_model))
            logits = y @ head_
            logits = lm._mask_pad_vocab(cfg, logits)
            lab = labmb.reshape(bsz, s)
            mx = jnp.max(logits, axis=-1).astype(jnp.float32)
            shifted = logits.astype(jnp.float32) - mx[..., None]
            logz = mx + jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
            ll = jnp.take_along_axis(
                logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
            mask = (lab >= 0).astype(jnp.float32)
            nll = jnp.sum((logz - ll.astype(jnp.float32)) * mask) \
                / jnp.maximum(jnp.sum(mask), 1.0)
            nll = jnp.where(me == stages - 1, nll, 0.0)
            return jax.lax.psum(nll, "pod")

        stage_ids = jnp.arange(mesh.shape["pod"], dtype=jnp.int32)
        # partial_manual_region: "data"/"model" stay auto inside this
        # shard_map, so on JAX 0.4.x the pipeline ring / inner loops must
        # take their partitioner-safe fallbacks (see repro.core.compat)
        with partial_manual_region():
            nll = shard_map(
                pod_body, mesh=mesh,
                in_specs=(P("pod"), P("pod"), P(None, None, None, None),
                          P(None, None, None), P(), P(None, None)),
                out_specs=P(),
                axis_names={"pod"}, check_vma=False,
            )(params["segments"][0]["layers"], stage_ids, x_mb, lab_mb,
              params["final_norm"], head)
    return nll, {"nll": nll, "aux": jnp.zeros((), jnp.float32)}


def pipeline_param_shardings(mesh, meta_tree, rules: Dict):
    """Like logical_shardings but the layer-stack leading dim goes to the
    pod axis (stage placement) instead of replication."""
    from repro.parallel.rules import logical_shardings
    base = logical_shardings(mesh, meta_tree, rules)

    def restage(path, sh):
        keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        if "segments" in keys and "layers" in keys:
            spec = list(sh.spec) + [None] * 8
            spec[0] = "pod"
            ndim = len(sh.spec)
            return NamedSharding(mesh, P(*spec[:max(ndim, 1)]))
        return sh

    return jax.tree_util.tree_map_with_path(restage, base)
