"""Logical-axis -> mesh-axis rule tables.

One model definition, three deployments:
  * 1 CPU device      : {}                        (everything replicated)
  * single pod (d, m)  : fsdp/dp -> data, tp/expert -> model
  * multi-pod (p, d, m): fsdp/dp -> (pod, data)   (ZeRO across all DP chips)

"dp" shards batch-like activation dims; "fsdp" shards weight dims (gathered
on use by GSPMD); "tp" is tensor parallelism; "expert" places MoE experts;
"sp" is the sequence/FFT slab axis.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.params import pspec_tree


def make_rules(mesh: jax.sharding.Mesh, pipeline_pods: bool = False,
               profile: str = "train") -> Dict[str, Any]:
    """profile: "train" gathers FSDP-sharded weights on use (ZeRO);
    "serve" keeps MoE expert weights stationary (d_ff sharded over data,
    contraction psums activations) — far fewer collective bytes when there
    is no optimizer to shard for."""
    axes = mesh.axis_names
    if "pod" in axes:
        dp = ("data",) if pipeline_pods else ("pod", "data")
        dp = dp if len(dp) > 1 else dp[0]
        rules = {"fsdp": dp, "dp": dp, "tp": "model", "expert": "model",
                 "sp": "data", "pipe": "pod"}
    elif "data" in axes:
        rules = {"fsdp": "data", "dp": "data", "tp": "model",
                 "expert": "model", "sp": "data"}
    else:
        return {}
    if profile == "serve":
        # weight-stationary MoE: experts live on the model axis, no FSDP
        # sharding of d/ff -> zero weight-gather collectives at inference
        rules["moe_d"] = None
        rules["moe_f"] = None
    else:
        rules["moe_d"] = rules["fsdp"]
        rules["moe_f"] = None
    return {k: v for k, v in rules.items() if v is not None}


def _axis_size(mesh: jax.sharding.Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def sanitize_spec(spec: P, shape, mesh: jax.sharding.Mesh) -> P:
    """Drop mesh axes that do not evenly divide their dimension (e.g. 4 mLSTM
    heads cannot shard over 16-way tensor parallelism — replicate instead)."""
    out = []
    for i, ax in enumerate(tuple(spec)):
        if ax is None or i >= len(shape):
            out.append(None)
            continue
        size = _axis_size(mesh, ax)
        out.append(ax if (size > 0 and shape[i] % size == 0
                          and shape[i] >= size) else None)
    return P(*out)


def logical_shardings(mesh: jax.sharding.Mesh, meta_tree, rules: Dict):
    """NamedSharding tree for a ParamMeta tree (divisibility-sanitized)."""
    from repro.models.params import ParamMeta, is_meta
    specs = pspec_tree(meta_tree, rules)

    def build(meta: ParamMeta, spec: P):
        return NamedSharding(mesh, sanitize_spec(spec, meta.shape, mesh))

    return jax.tree_util.tree_map(build, meta_tree, specs, is_leaf=is_meta)


def sanitized_shardings(mesh: jax.sharding.Mesh, abstract_tree, spec_tree):
    """NamedSharding tree for a ShapeDtypeStruct tree + PartitionSpec tree."""
    def build(abs_, spec):
        return NamedSharding(mesh, sanitize_spec(spec, abs_.shape, mesh))
    return jax.tree_util.tree_map(
        build, abstract_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"))
