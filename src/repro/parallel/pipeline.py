"""Pod-axis pipeline parallelism (GPipe microbatching via collective_permute).

Multi-pod meshes pay DCN latency for every cross-pod collective.  FSDP over
(pod, data) all-gathers weights across pods every layer; pipelining instead
confines cross-pod traffic to *stage boundaries*: one (mb, S, d) activation
per microbatch tick, a ~100x bytes reduction for large models.

SPMD schedule: all pods run the same program; at tick t, the pod holding
stage s computes microbatch (t - s) and ppermutes its output to stage s+1.
Ticks = M + S - 1; the (S-1)/M bubble is the classic GPipe trade-off.
Autodiff transposes ppermute to the reverse ring, so one forward definition
trains.  Stage-sliced layer parameters arrive sharded over the pod axis.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.compat import legacy_partial_manual, pvary, ring_shift


def pipeline_stages(stage_fn: Callable[[Any, jax.Array], jax.Array],
                    stage_params: Any, x_mb: jax.Array, axis: str,
                    me: jax.Array | None = None):
    """Like :func:`pipeline_forward` but WITHOUT the final broadcast: returns
    (outs, my_stage_index, num_stages) where ``outs`` holds valid microbatch
    outputs only on the last stage (zeros elsewhere).  Callers that reduce to
    a scalar (the LM loss) mask by stage and psum — no activation ever
    crosses the pod axis outside the ppermute ring.

    ``me`` optionally supplies the caller's stage index as data (an iota
    sharded over ``axis``) — REQUIRED under partial-manual shard_map on JAX
    0.4.x, where ``axis_index`` cannot lower (see repro.core.compat)."""
    return _pipeline_impl(stage_fn, stage_params, x_mb, axis, me)


def pipeline_forward(stage_fn: Callable[[Any, jax.Array], jax.Array],
                     stage_params: Any, x_mb: jax.Array, axis: str
                     ) -> jax.Array:
    """Run microbatches through pipeline stages along mesh axis ``axis``.

    stage_fn(params_local, x) applies THIS pod's stage.
    x_mb: (M, mb, ...) microbatched inputs (replicated over ``axis``).
    Returns (M, mb, ...) outputs of the LAST stage (valid on every pod after
    the final broadcast permute).
    Must be called inside shard_map with ``axis`` in scope.
    """
    outs, me, s = _pipeline_impl(stage_fn, stage_params, x_mb, axis)
    # broadcast final outputs from the last stage to every pod so downstream
    # (loss) is SPMD-consistent.  (all_gather + static index rather than a
    # masked psum: XLA 0.8's ChangeOpDataType pass crashes cloning the
    # masked all-reduce on the multi-pod mesh.)
    outs_all = jax.lax.all_gather(outs, axis)                   # (S, M, mb, ..)
    return outs_all[s - 1]


def _pipeline_impl(stage_fn, stage_params, x_mb, axis: str, me=None):
    s = jax.lax.psum(1, axis)                                   # stage count
    if me is None:          # full-manual meshes: axis_index lowers everywhere
        me = jax.lax.axis_index(axis)
    m = x_mb.shape[0]
    ticks = m + s - 1
    # The injected microbatch is only CONSUMED on stage 0, where t - me == t,
    # so the schedule index stays axis-invariant — required on JAX 0.4.x,
    # whose partitioner cannot lower a manual-axis-varying gather of a
    # region input.

    if legacy_partial_manual():
        # 0.4.x partial-manual region: GSPMD cannot partition a while-loop
        # whose body mixes manual-subgroup collectives with gathers of
        # region inputs (hlo_sharding_util CHECK failure), so the tick loop
        # unrolls — ticks is static and small (M + S - 1).
        buf = pvary(jnp.zeros(x_mb.shape[1:], x_mb.dtype), (axis,))
        ys = []
        for t in range(ticks):
            inp = jnp.where(me == 0, x_mb[min(t, m - 1)], buf)
            ys.append(stage_fn(stage_params, inp))
            buf = ring_shift(ys[-1], axis, me, s)
        # tick t completes microbatch t - (s-1) on the last stage
        outs = jnp.stack(ys[s - 1:s - 1 + m])
        outs = jnp.where(me == s - 1, outs, jnp.zeros_like(outs))
        return outs, me, s

    def tick(carry, t):
        buf, outs = carry                                       # buf: (mb, ...)
        mb_idx = jnp.clip(t, 0, m - 1)
        inject = jax.lax.dynamic_index_in_dim(x_mb, mb_idx, 0, keepdims=False)
        inp = jnp.where(me == 0, inject, buf)
        out = stage_fn(stage_params, inp)
        # last stage stores its result for microbatch t - (s-1)
        done_idx = jnp.clip(t - (s - 1), 0, m - 1)
        store = jnp.logical_and(me == s - 1, t >= s - 1)
        upd = jax.lax.dynamic_update_index_in_dim(outs, out, done_idx, 0)
        outs = jnp.where(store, upd, outs)
        buf = ring_shift(out, axis, me, s)
        return (buf, outs), None

    out_shape = jax.eval_shape(stage_fn, stage_params, x_mb[0])
    buf0 = pvary(jnp.zeros(x_mb.shape[1:], x_mb.dtype), (axis,))
    outs0 = pvary(
        jnp.zeros((m,) + out_shape.shape, out_shape.dtype), (axis,))
    (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
    return outs, me, s
