from .rules import (make_rules, logical_shardings, sanitize_spec,
                    sanitized_shardings)
from .pipeline import pipeline_forward, pipeline_stages

__all__ = ["make_rules", "logical_shardings", "sanitize_spec",
           "sanitized_shardings", "pipeline_forward", "pipeline_stages"]
