"""AdamW with cosine schedule and global-norm clipping, as pure pytree ops.

Optimizer state mirrors the parameter tree, so the same ParamMeta sharding
rules apply — Adam moments are FSDP-sharded exactly like their parameters
(ZeRO-style), which is what makes 100B-scale training fit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamMeta, is_meta


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def opt_meta(meta_tree) -> Dict[str, Any]:
    """ParamMeta tree for optimizer state (mu, nu mirror params; f32)."""
    def moment(m: ParamMeta) -> ParamMeta:
        return ParamMeta(m.shape, m.logical, init="zeros", dtype=jnp.float32)
    mirror = jax.tree_util.tree_map(moment, meta_tree, is_leaf=is_meta)
    return {"mu": mirror, "nu": mirror,
            "step": ParamMeta((), (), init="zeros", dtype=jnp.int32)}


def adamw_init(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, params, state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, p, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(g, p, mu, nu) for g, p, mu, nu
           in zip(flat_g, flat_p, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
