"""Int8 error-feedback gradient compression for cross-pod (DCN) reduction.

Between pods the links are the scarce resource (DCN << ICI).  When multi-pod
training runs plain DP over the pod axis (instead of pod-FSDP), the gradient
all-reduce can run in int8 with per-block scales and an error-feedback
accumulator: wire bytes drop ~3.5x vs f32 ring all-reduce at p=2, and the
quantization error is re-injected next step (Karimireddy et al., EF-SGD),
keeping convergence intact.

``compressed_psum`` is a shard_map-level collective: quantize locally,
all_gather the int8 payload + scales over ``axis`` (through the shared
pair-collective layer in :mod:`repro.core.comm`), dequantize-and-sum
locally.  For p pods the wire cost is p * (n + n/block * 2) bytes vs
2 * 4n * (p-1)/p for the f32 ring.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import all_gather_pair

BLOCK = 256


def quantize_int8(x: jax.Array, block: int = BLOCK
                  ) -> Tuple[jax.Array, jax.Array, int]:
    """Per-block symmetric int8 quantization. Returns (q, scales, pad)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16), pad


def dequantize_int8(q: jax.Array, scale: jax.Array, pad: int,
                    shape) -> jax.Array:
    deq = (q.astype(jnp.float32) * scale.astype(jnp.float32)).reshape(-1)
    if pad:
        deq = deq[:-pad]
    return deq.reshape(shape)


def compressed_psum(x: jax.Array, axis_name: str,
                    error: jax.Array | None = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    Returns (summed value f32, new error-feedback residual)."""
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    q, scale, pad = quantize_int8(xf)
    local_deq = dequantize_int8(q, scale, pad, xf.shape)
    new_error = xf - local_deq

    qg, sg = all_gather_pair((q, scale), axis_name)             # (P, nb, B) int8,
    #                                                             (P, nb, 1) bf16
    deq = qg.astype(jnp.float32) * sg.astype(jnp.float32)
    total = jnp.sum(deq, axis=0).reshape(-1)
    if pad:
        total = total[:-pad]
    return total.reshape(x.shape), new_error
