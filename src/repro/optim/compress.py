"""Int8 error-feedback gradient compression for cross-pod (DCN) reduction.

Between pods the links are the scarce resource (DCN << ICI).  When multi-pod
training runs plain DP over the pod axis (instead of pod-FSDP), the gradient
all-reduce can run in int8 with per-block scales and an error-feedback
accumulator: wire bytes drop ~3.5x vs f32 ring all-reduce at p=2, and the
quantization error is re-injected next step (Karimireddy et al., EF-SGD),
keeping convergence intact.

``compressed_psum`` is a shard_map-level collective: quantize locally,
all_gather the int8 payload + scales over ``axis`` (through the shared
pair-collective layer in :mod:`repro.core.comm`), dequantize-and-sum
locally.  For p pods the wire cost is p * (n + n/block * 2) bytes vs
2 * 4n * (p-1)/p for the f32 ring.

The gather rides the swappable comm subsystem: ``comm="collective"`` is one
monolithic all_gather, ``comm="pipelined[:c]"`` cuts the payload into
overlap-ready chunks.  ``compressed_psum`` runs INSIDE shard_map, so the
``"auto"``/``"measure"`` modes can't resolve there — call
:func:`choose_psum_comm` outside (it knows the mesh) and pass the verdict
in, mirroring how the FFT entry points resolve their ``comm`` argument.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import (CommSpec, get_backend, measure_comm_gather,
                             plan_comm_gather)

BLOCK = 256


def quantize_int8(x: jax.Array, block: int = BLOCK
                  ) -> Tuple[jax.Array, jax.Array, int]:
    """Per-block symmetric int8 quantization. Returns (q, scales, pad)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16), pad


def dequantize_int8(q: jax.Array, scale: jax.Array, pad: int,
                    shape) -> jax.Array:
    deq = (q.astype(jnp.float32) * scale.astype(jnp.float32)).reshape(-1)
    if pad:
        deq = deq[:-pad]
    return deq.reshape(shape)


def choose_psum_comm(mesh, axis_name: str, shape, mode: str = "auto",
                     wisdom=None, hw=None, planner=None) -> str:
    """Resolve a ``comm`` spec for :func:`compressed_psum` OUTSIDE shard_map.

    ``mode="auto"`` applies the gather roofline
    (:func:`repro.core.comm.plan_comm_gather`) for the ``hw`` profile
    (default TPU_V5E); ``mode="measure"`` times the monolithic vs chunked
    gathers on the live mesh for this payload size
    (:func:`repro.core.comm.measure_comm_gather`), caching the verdict under
    a ``comm/gather/*`` wisdom key.  Any other mode is passed through
    verbatim, so callers can thread one config string end to end.

    Pass ``planner=`` to resolve against the same hardware profile and
    unified wisdom store the FFT front-end (:func:`repro.core.api.plan_nd`)
    plans with — one planner, every autotuned choice.
    """
    if planner is not None:
        hw = hw or planner.hw
        wisdom = wisdom if wisdom is not None else planner.wisdom
    n = math.prod(shape)
    if mode == "auto":
        return plan_comm_gather(n, mesh.shape[axis_name], block=BLOCK, hw=hw)
    if mode == "measure":
        return measure_comm_gather(mesh, axis_name, n, block=BLOCK,
                                   wisdom=wisdom)
    return mode


def compressed_psum(x: jax.Array, axis_name: str,
                    error: jax.Array | None = None,
                    comm: CommSpec = "collective", chunks: int = 4
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    ``comm`` selects the gather backend (resolve ``"auto"``/``"measure"``
    via :func:`choose_psum_comm` first).  Returns (summed value f32, new
    error-feedback residual)."""
    backend = get_backend(comm, chunks=chunks)
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    q, scale, pad = quantize_int8(xf)
    local_deq = dequantize_int8(q, scale, pad, xf.shape)
    new_error = xf - local_deq

    qg, sg = backend.gather((q, scale), axis_name)              # (P, nb, B) int8,
    #                                                             (P, nb, 1) bf16
    deq = qg.astype(jnp.float32) * sg.astype(jnp.float32)
    total = jnp.sum(deq, axis=0).reshape(-1)
    if pad:
        total = total[:-pad]
    return total.reshape(x.shape), new_error
