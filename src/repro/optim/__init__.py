from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule, opt_meta
from .compress import (choose_psum_comm, compressed_psum, dequantize_int8,
                       quantize_int8)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "opt_meta", "quantize_int8", "dequantize_int8", "compressed_psum",
           "choose_psum_comm"]
