"""Core FFT algorithm vs numpy oracle."""

import numpy as np
import pytest

from repro.core import algo

RNG = np.random.default_rng(0)


def _rand_c(shape):
    return (RNG.standard_normal(shape).astype(np.float32)
            + 1j * RNG.standard_normal(shape).astype(np.float32))


@pytest.mark.parametrize("n", [2, 4, 8, 16, 64, 128, 256, 512, 1024, 4096])
@pytest.mark.parametrize("karatsuba", [False, True])
def test_fft_matches_numpy(n, karatsuba):
    x = _rand_c((3, n))
    ours = algo.to_complex(algo.fft(algo.to_pair(x), karatsuba=karatsuba))
    ref = np.fft.fft(x)
    np.testing.assert_allclose(np.asarray(ours), ref,
                               rtol=2e-4, atol=2e-4 * np.abs(ref).max())


@pytest.mark.parametrize("n", [96, 384, 768, 1536])
def test_fft_non_pow2(n):
    """Factorable non-powers-of-two (the planner handles any smooth n)."""
    x = _rand_c((2, n))
    ours = algo.to_complex(algo.fft(algo.to_pair(x)))
    ref = np.fft.fft(x)
    np.testing.assert_allclose(np.asarray(ours), ref,
                               rtol=2e-4, atol=2e-4 * np.abs(ref).max())


@pytest.mark.parametrize("n", [64, 256, 2048])
def test_ifft_roundtrip(n):
    x = _rand_c((2, n))
    back = algo.to_complex(algo.ifft(algo.fft(algo.to_pair(x))))
    np.testing.assert_allclose(np.asarray(back), x, atol=2e-5 * n)


@pytest.mark.parametrize("n", [256, 1024, 16384])
def test_permuted_roundtrip(n):
    f2 = algo.default_factorization(n)
    if len(f2) != 2:
        pytest.skip("permuted mode is two-factor only")
    x = _rand_c((2, n))
    p = algo.fft(algo.to_pair(x), factors=f2, permuted=True)
    back = algo.to_complex(algo.ifft_from_permuted(p, factors=f2))
    np.testing.assert_allclose(np.asarray(back), x, atol=2e-5 * n)


def test_permuted_is_permutation():
    n = 256
    f2 = algo.default_factorization(n)
    x = _rand_c((1, n))
    ordered = algo.to_complex(algo.fft(algo.to_pair(x), factors=f2))
    perm = algo.to_complex(algo.fft(algo.to_pair(x), factors=f2, permuted=True))
    o = np.sort_complex(np.round(np.asarray(ordered).ravel(), 3))
    p = np.sort_complex(np.round(np.asarray(perm).ravel(), 3))
    np.testing.assert_allclose(o, p, atol=1e-2)


@pytest.mark.parametrize("n", [16, 128, 512, 4096])
def test_rfft_irfft(n):
    x = RNG.standard_normal((4, n)).astype(np.float32)
    ours = algo.to_complex(algo.rfft(x))
    np.testing.assert_allclose(np.asarray(ours), np.fft.rfft(x),
                               rtol=2e-4, atol=2e-4 * n)
    back = algo.irfft(algo.to_pair(np.fft.rfft(x).astype(np.complex64)))
    np.testing.assert_allclose(np.asarray(back), x, atol=2e-5 * n)


@pytest.mark.parametrize("shape", [(32, 64), (128, 128), (64, 256)])
def test_rfft2(shape):
    x = RNG.standard_normal(shape).astype(np.float32)
    ours = algo.to_complex(algo.rfft2(x))
    ref = np.fft.rfft2(x)
    np.testing.assert_allclose(np.asarray(ours), ref,
                               rtol=2e-4, atol=2e-4 * np.abs(ref).max())


def test_fftn_3d():
    x = _rand_c((8, 16, 32))
    ours = algo.to_complex(algo.fftn(algo.to_pair(x), ndim=3))
    ref = np.fft.fftn(x)
    np.testing.assert_allclose(np.asarray(ours), ref,
                               rtol=3e-4, atol=3e-4 * np.abs(ref).max())


def test_factorization_properties():
    for n in [128, 256, 4096, 16384, 2 ** 19, 96]:
        fs = algo.default_factorization(n)
        assert np.prod(fs) == n
        assert all(f <= 128 for f in fs)
