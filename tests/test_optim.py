"""Optimizer + compression unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, dequantize_int8, quantize_int8)


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200, clip_norm=100.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(cfg, grads, params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s)))
           for s in [0, 5, 10, 60, 110, 200]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6          # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6          # peak
    assert 0.1 < lrs[3] < 1.0                # mid-decay
    assert abs(lrs[4] - 0.1) < 1e-2          # floor
    assert abs(lrs[5] - 0.1) < 1e-2          # clamped


def test_grad_clipping_applies():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    _, state, m = adamw_update(cfg, {"w": jnp.full(4, 100.0)}, params, state)
    assert float(m["grad_norm"]) > 100
    # with lr=0 params don't move but moments got the CLIPPED grad
    mu = float(jnp.max(jnp.abs(state["mu"]["w"])))
    assert mu <= (1 - cfg.b1) * (1.0 / 2 + 1e-3)   # clipped to norm 1


@pytest.mark.parametrize("shape", [(7,), (1000,), (33, 59)])
def test_quantize_roundtrip_error(shape):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape) * 5, jnp.float32)
    q, s, pad = quantize_int8(x)
    back = dequantize_int8(q, s, pad, x.shape)
    # per-block symmetric int8: error bounded by scale/2 per element
    max_per_block = np.abs(np.asarray(x)).max()
    assert float(jnp.max(jnp.abs(back - x))) <= max_per_block / 127 + 1e-6


def test_quantize_preserves_zeros():
    x = jnp.zeros((512,), jnp.float32)
    q, s, pad = quantize_int8(x)
    back = dequantize_int8(q, s, pad, x.shape)
    np.testing.assert_array_equal(np.asarray(back), 0.0)


def test_opt_meta_mirrors_params():
    from repro.models import lm
    from repro.optim import opt_meta
    from repro.configs import get_smoke_config
    from repro.models.params import abstract_tree
    meta = lm.model_meta(get_smoke_config("granite_8b"))
    om = opt_meta(meta)
    pa = abstract_tree(meta)
    ma = abstract_tree(om["mu"])
    assert jax.tree_util.tree_structure(pa) == jax.tree_util.tree_structure(ma)
    for p, m in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(ma)):
        assert p.shape == m.shape
