"""Per-assigned-architecture smoke tests: reduced config of the same family,
one forward + one train step on CPU; output shapes + finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import frontend, lm
from repro.models.config import SHAPES_BY_NAME
from repro.models.lm import padded_vocab

B, S = 2, 16


def _batch(cfg, key=0):
    kt, ke = jax.random.split(jax.random.key(key))
    if cfg.frontend:
        batch = {"embeds": frontend.synth_embeddings(cfg, B, S, ke),
                 "labels": jax.random.randint(kt, (B, S), 0, cfg.vocab_size)}
    else:
        toks = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
    if cfg.rope == "mrope":
        batch["positions"] = frontend.mrope_positions(B, S, grid_hw=2)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.key(1))
    batch = _batch(cfg)

    logits, aux = jax.jit(lambda p, b: lm.forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def loss(p):
        return lm.loss_fn(p, cfg, batch)[0]

    l0, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(l0))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0

    # one SGD step reduces nothing necessarily, but must stay finite
    params2 = jax.tree_util.tree_map(
        lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    l1 = float(jax.jit(loss)(params2))
    assert np.isfinite(l1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.key(2))
    cache = lm.init_cache(cfg, B, max_len=S)
    if cfg.frontend:
        batch = {"embeds": frontend.synth_embeddings(cfg, B, 1,
                                                     jax.random.key(3))}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, cache2 = jax.jit(
        lambda p, c, b: lm.decode_step(p, cfg, c, b))(params, cache, batch)
    assert logits.shape == (B, 1, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["len"][0]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact pool hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "granite_8b": (36, 4096, 32, 8, 14336, 49152),
        "olmo_1b": (16, 2048, 16, 16, 8192, 50304),
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "granite_3_2b": (40, 2048, 32, 8, 8192, 49155),
        "phi35_moe_42b": (32, 4096, 32, 8, 6400, 32064),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)
    assert cfg.total_layers() == cfg.num_layers
    if arch == "phi35_moe_42b":
        assert (cfg.num_experts, cfg.top_k) == (16, 2)
    if arch == "dbrx_132b":
        assert (cfg.num_experts, cfg.top_k) == (16, 4)
    if arch == "zamba2_7b":
        assert cfg.ssm_state == 64
    if arch == "qwen2_vl_7b":
        assert cfg.rope == "mrope"


def test_param_counts_are_plausible():
    """Sanity-check total parameter counts against the pool's model names."""
    from repro.models.params import param_count
    expect = {"granite_8b": (7e9, 10e9), "olmo_1b": (0.9e9, 1.6e9),
              "command_r_plus_104b": (90e9, 120e9),
              "granite_3_2b": (2e9, 3.3e9),
              "phi35_moe_42b": (38e9, 46e9), "dbrx_132b": (120e9, 140e9),
              "xlstm_1_3b": (1.0e9, 1.9e9), "zamba2_7b": (5e9, 9e9),
              "qwen2_vl_7b": (6.5e9, 9e9), "musicgen_large": (1.5e9, 2.8e9)}
    for arch, (lo, hi) in expect.items():
        n = param_count(lm.model_meta(get_config(arch)))
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"
