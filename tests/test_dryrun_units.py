"""Dry-run machinery unit tests (no 512-device compile here)."""

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.dryrun import (_shape_bytes, inner_scan_flops_correction,
                                 model_flops, parse_collectives)
from repro.configs import get_config
from repro.models.config import SHAPES_BY_NAME

HLO_SAMPLE = """
ENTRY %main {
  %ag = f32[16,4096]{1,0} all-gather(%p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%x), replica_groups=[2,16]<=[32], to_apply=%sum
  %rs = f32[8,128]{1,0} reduce-scatter(%y), replica_groups={{0,1}}, dimensions={0}
  %a2a = f32[64,64]{1,0} all-to-all(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[32]{0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %ignored = f32[4]{0} add(%a, %b)
}
"""


def test_parse_collectives_bytes():
    out, counts = parse_collectives(HLO_SAMPLE)
    _, _, wire = parse_collectives(HLO_SAMPLE, with_wire=True)
    # AGAS-style all-gather wire cost = result - operand
    assert wire["all-gather"] == 16 * 4096 * 4 * 3 / 4
    assert counts == {"all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
                      "all-to-all": 1, "collective-permute": 1}
    assert out["all-gather"] == 16 * 4096 * 4 / 4     # result / group
    assert out["all-reduce"] == 1024 * 2              # == result
    assert out["reduce-scatter"] == 8 * 128 * 4 * 2   # result * group
    assert out["all-to-all"] == 64 * 64 * 4
    assert out["collective-permute"] == 32 * 4


def test_shape_bytes_tuple():
    assert _shape_bytes("(f32[2,2], bf16[8])") == 16 + 16


def test_model_flops_moe_discounts_inactive_experts():
    dense = model_flops(get_config("granite_8b"), SHAPES_BY_NAME["train_4k"])
    moe = model_flops(get_config("phi35_moe_42b"), SHAPES_BY_NAME["train_4k"])
    # phi3.5-moe has 42B params but only ~6.6B active
    assert moe < 12e9 * 6 * 256 * 4096
    assert dense > 7e9 * 6 * 256 * 4096


def test_inner_scan_correction_positive_for_attention():
    c = inner_scan_flops_correction(get_config("granite_8b"),
                                    SHAPES_BY_NAME["prefill_32k"])
    assert c > 0
    # decode has no rolled inner scans
    assert inner_scan_flops_correction(get_config("granite_8b"),
                                       SHAPES_BY_NAME["decode_32k"]) == 0


def test_sanitize_spec_drops_nondividing_axes():
    import jax
    from repro.parallel import sanitize_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    s = sanitize_spec(P("model", None), (4, 7), FakeMesh)
    assert s == P(None, None)
    s2 = sanitize_spec(P("data", "model"), (32, 64), FakeMesh)
    assert s2 == P("data", "model")
