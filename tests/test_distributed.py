"""Multi-device (8 fake CPU devices) integration tests, run in a subprocess
so the XLA device-count override never leaks into this process."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_distributed_suite():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_dist_worker.py")],
        env=env, capture_output=True, text=True, timeout=2400)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0
    assert "ALL_DIST_OK" in proc.stdout
