"""Planner behaviour: estimate vs measured, wisdom, cost model."""

import os

import jax
import numpy as np
import pytest

from repro.core import algo, plan


def test_estimate_plan_valid():
    p = plan.Planner(mode="estimate", backends=("jnp",))
    pl = p.plan(4096, "c2c", batch=8)
    assert np.prod(pl.factors) == 4096
    assert all(f <= 128 for f in pl.factors)


def test_estimate_prefers_mxu_sized_factors():
    """The v5e cost model penalizes tiny factors (MXU underutilization)."""
    p = plan.Planner(hardware=plan.TPU_V5E, mode="estimate", backends=("jnp",))
    pl = p.plan(16384, "c2c", batch=64)
    assert min(pl.factors) >= 32, pl.factors


def test_measured_planning_runs_and_caches(tmp_path):
    w = str(tmp_path / "wisdom.json")
    p = plan.Planner(mode="measured", backends=("jnp", "xla_native"),
                     hardware=plan.CPU_LOCAL, wisdom_path=w)
    pl = p.plan(512, "c2c", batch=16)
    assert pl.measured_cost > 0
    t_first = p.last_plan_seconds
    assert t_first > 0
    pl2 = p.plan(512, "c2c", batch=16)       # wisdom hit
    assert p.last_plan_seconds == 0.0
    assert pl2.factors == pl.factors
    # wisdom persists across planner instances (FFTW wisdom file semantics)
    p3 = plan.Planner(mode="measured", backends=("jnp", "xla_native"),
                      hardware=plan.CPU_LOCAL, wisdom_path=w)
    p3.plan(512, "c2c", batch=16)
    assert p3.last_plan_seconds == 0.0


def test_execute_matches_backend_choices():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 1024)).astype(np.float32)
    ref = np.fft.fft(x)
    for backend in ("jnp", "jnp_karatsuba", "xla_native", "pallas"):
        p = plan.Planner(mode="estimate", backends=(backend,))
        pl = p.plan(1024, "c2c")
        out = plan.execute(pl, algo.to_pair(x.astype(np.complex64)))
        z = np.asarray(out[0]) + 1j * np.asarray(out[1])
        if pl.permuted:
            continue
        np.testing.assert_allclose(z, ref, rtol=2e-4,
                                   atol=2e-4 * np.abs(ref).max())


def test_plan_flops_karatsuba_saves_quarter():
    p4 = plan.Plan(4096, "c2c", (64, 64), "jnp")
    p3 = plan.Plan(4096, "c2c", (64, 64), "jnp_karatsuba")
    assert abs(p3.flops(1) / p4.flops(1) - 0.75) < 1e-6


def test_inverse_execute():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((2, 256)).astype(np.float32) \
        + 1j * rng.standard_normal((2, 256)).astype(np.float32)
    for backend in ("jnp", "xla_native"):
        p = plan.Planner(mode="estimate", backends=(backend,))
        pl = p.plan(256, "c2c")
        back = plan.execute_inverse(pl, plan.execute(pl, algo.to_pair(x)))
        z = np.asarray(back[0]) + 1j * np.asarray(back[1])
        np.testing.assert_allclose(z, x, atol=1e-3)


def test_wisdom_key_includes_batch_bucket():
    """Regression: a plan measured at batch=1 must not be silently reused at
    batch=4096 — the wisdom key carries a log2 batch bucket."""
    p = plan.Planner(mode="estimate", backends=("jnp",))
    p.plan(1024, "c2c", batch=1)
    assert len(list(p.wisdom.keys("plan/"))) == 1
    p.plan(1024, "c2c", batch=4096)          # different bucket: new entry
    assert len(list(p.wisdom.keys("plan/"))) == 2
    assert p.last_plan_seconds > 0.0
    p.plan(1024, "c2c", batch=4096)          # same bucket: wisdom hit
    assert p.last_plan_seconds == 0.0
    p.plan(1024, "c2c", batch=5000)          # 4096..8191 share bucket 12
    assert p.last_plan_seconds == 0.0
    assert len(list(p.wisdom.keys("plan/"))) == 2


@pytest.mark.parametrize("content", [
    "",                                       # empty file
    "{ not json",                             # corrupt
    '["wrong", "container"]',                 # valid JSON, wrong shape
    '{"1024/c2c/estimate": {"factors": []}}',  # pre-wisdom flat schema
    '{"schema": "repro-wisdom", "version": 999, "entries": {}}',  # stale
])
def test_corrupt_wisdom_file_degrades_to_empty(tmp_path, content):
    """A broken wisdom file must warn and start empty, never crash."""
    w = tmp_path / "wisdom.json"
    w.write_text(content)
    with pytest.warns(UserWarning):
        p = plan.Planner(mode="estimate", backends=("jnp",),
                         wisdom_path=str(w))
    assert len(p.wisdom) == 0
    pl = p.plan(256, "c2c")                   # planner still fully functional
    assert np.prod(pl.factors) == 256
    # and the rewrite produced a loadable, current-schema file
    p2 = plan.Planner(mode="estimate", backends=("jnp",), wisdom_path=str(w))
    p2.plan(256, "c2c")
    assert p2.last_plan_seconds == 0.0


def test_wisdom_export_import_byte_identical(tmp_path):
    """FFTW-style wisdom string API: export -> import -> export is
    byte-identical, including measured-mode entries."""
    p = plan.Planner(mode="measured", backends=("jnp", "xla_native"),
                     hardware=plan.CPU_LOCAL)
    p.plan(128, "c2c", batch=4)
    p.plan(64, "r2c")
    text = p.export_wisdom()
    p2 = plan.Planner(mode="measured", backends=("jnp", "xla_native"),
                      hardware=plan.CPU_LOCAL)
    assert p2.import_wisdom(text) == 2
    assert p2.export_wisdom() == text
    p2.plan(128, "c2c", batch=4)              # imported wisdom serves plans
    assert p2.last_plan_seconds == 0.0
    # forget_wisdom by namespace mirrors fftw_forget_wisdom
    assert p2.forget_wisdom("plan/") == 2
    assert len(p2.wisdom) == 0
    with pytest.raises(ValueError):
        p2.import_wisdom('{"schema": "other", "version": 1, "entries": {}}')


@pytest.mark.parametrize("backend", ["jnp", "jnp_karatsuba", "xla_native",
                                     "pallas", "pallas_karatsuba"])
@pytest.mark.parametrize("kind", ["c2c", "r2c"])
def test_plan_roundtrip_matrix(backend, kind):
    """execute/execute_inverse (or the r2c/c2r plan pair) round-trips for
    every kind x backend a Plan can hold."""
    n = 256
    rng = np.random.default_rng(7)
    p = plan.Planner(mode="estimate", backends=(backend,))
    if kind == "c2c":
        x = (rng.standard_normal((2, n)) +
             1j * rng.standard_normal((2, n))).astype(np.complex64)
        pl = p.plan(n, "c2c")
        back = plan.execute_inverse(pl, plan.execute(pl, algo.to_pair(x)))
        z = np.asarray(back[0]) + 1j * np.asarray(back[1])
        np.testing.assert_allclose(z, x, atol=2e-3)
    else:
        x = rng.standard_normal((2, n)).astype(np.float32)
        fwd = p.plan(n, "r2c")
        inv = p.plan(n, "c2r")
        back = plan.execute(inv, plan.execute(fwd, x))
        np.testing.assert_allclose(np.asarray(back), x, atol=2e-3)
