"""Planner behaviour: estimate vs measured, wisdom, cost model."""

import os

import jax
import numpy as np
import pytest

from repro.core import algo, plan


def test_estimate_plan_valid():
    p = plan.Planner(mode="estimate", backends=("jnp",))
    pl = p.plan(4096, "c2c", batch=8)
    assert np.prod(pl.factors) == 4096
    assert all(f <= 128 for f in pl.factors)


def test_estimate_prefers_mxu_sized_factors():
    """The v5e cost model penalizes tiny factors (MXU underutilization)."""
    p = plan.Planner(hardware=plan.TPU_V5E, mode="estimate", backends=("jnp",))
    pl = p.plan(16384, "c2c", batch=64)
    assert min(pl.factors) >= 32, pl.factors


def test_measured_planning_runs_and_caches(tmp_path):
    w = str(tmp_path / "wisdom.json")
    p = plan.Planner(mode="measured", backends=("jnp", "xla_native"),
                     hardware=plan.CPU_LOCAL, wisdom_path=w)
    pl = p.plan(512, "c2c", batch=16)
    assert pl.measured_cost > 0
    t_first = p.last_plan_seconds
    assert t_first > 0
    pl2 = p.plan(512, "c2c", batch=16)       # wisdom hit
    assert p.last_plan_seconds == 0.0
    assert pl2.factors == pl.factors
    # wisdom persists across planner instances (FFTW wisdom file semantics)
    p3 = plan.Planner(mode="measured", backends=("jnp", "xla_native"),
                      hardware=plan.CPU_LOCAL, wisdom_path=w)
    p3.plan(512, "c2c", batch=16)
    assert p3.last_plan_seconds == 0.0


def test_execute_matches_backend_choices():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 1024)).astype(np.float32)
    ref = np.fft.fft(x)
    for backend in ("jnp", "jnp_karatsuba", "xla_native", "pallas"):
        p = plan.Planner(mode="estimate", backends=(backend,))
        pl = p.plan(1024, "c2c")
        out = plan.execute(pl, algo.to_pair(x.astype(np.complex64)))
        z = np.asarray(out[0]) + 1j * np.asarray(out[1])
        if pl.permuted:
            continue
        np.testing.assert_allclose(z, ref, rtol=2e-4,
                                   atol=2e-4 * np.abs(ref).max())


def test_plan_flops_karatsuba_saves_quarter():
    p4 = plan.Plan(4096, "c2c", (64, 64), "jnp")
    p3 = plan.Plan(4096, "c2c", (64, 64), "jnp_karatsuba")
    assert abs(p3.flops(1) / p4.flops(1) - 0.75) < 1e-6


def test_inverse_execute():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((2, 256)).astype(np.float32) \
        + 1j * rng.standard_normal((2, 256)).astype(np.float32)
    for backend in ("jnp", "xla_native"):
        p = plan.Planner(mode="estimate", backends=(backend,))
        pl = p.plan(256, "c2c")
        back = plan.execute_inverse(pl, plan.execute(pl, algo.to_pair(x)))
        z = np.asarray(back[0]) + 1j * np.asarray(back[1])
        np.testing.assert_allclose(z, x, atol=1e-3)
