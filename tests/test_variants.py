"""The paper's implementation variants all compute the same transform."""

import jax
import numpy as np
import pytest

from repro.core import plan, variants

RNG = np.random.default_rng(1)
PLANNER = plan.Planner(mode="estimate", backends=("jnp",))


@pytest.mark.parametrize("variant", list(variants.VARIANTS) + ["strided"])
@pytest.mark.parametrize("shape", [(32, 64), (64, 128)])
def test_variant_matches_numpy(variant, shape):
    x = RNG.standard_normal(shape).astype(np.float32)
    ref = np.fft.rfft2(x)
    out = jax.jit(lambda a: variants.run_variant(variant, a, PLANNER,
                                                 task_size=8))(x)
    z = np.asarray(out[0]) + 1j * np.asarray(out[1])
    np.testing.assert_allclose(z, ref, rtol=2e-4,
                               atol=2e-4 * np.abs(ref).max())


@pytest.mark.parametrize("task_size", [1, 2, 8, 32])
def test_task_size_invariance(task_size):
    """The paper's task-size knob must never change results."""
    x = RNG.standard_normal((32, 64)).astype(np.float32)
    ref = np.fft.rfft2(x)
    for v in ("future_naive", "future_opt"):
        out = variants.run_variant(v, x, PLANNER, task_size=task_size)
        z = np.asarray(out[0]) + 1j * np.asarray(out[1])
        np.testing.assert_allclose(z, ref, rtol=2e-4,
                                   atol=2e-4 * np.abs(ref).max())


def test_staged_for_loop_composes():
    x = RNG.standard_normal((32, 64)).astype(np.float32)
    ref = np.fft.rfft2(x)
    stages = variants.staged_for_loop(x, PLANNER)
    val = x
    for _, fn in stages:
        val = fn(val)
    z = np.asarray(val[0]) + 1j * np.asarray(val[1])
    np.testing.assert_allclose(z, ref, rtol=2e-4,
                               atol=2e-4 * np.abs(ref).max())
