"""Data pipeline: determinism, shapes, labels, mrope positions."""

import numpy as np

from repro.configs import get_smoke_config
from repro.data import SyntheticDataset
from repro.models.config import ShapeConfig


def test_deterministic_batches():
    cfg = get_smoke_config("granite_8b")
    shape = ShapeConfig("t", 64, 4, "train")
    a = SyntheticDataset(cfg, shape, seed=7).batch_at(3)
    b = SyntheticDataset(cfg, shape, seed=7).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticDataset(cfg, shape, seed=8).batch_at(3)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_smoke_config("granite_8b")
    shape = ShapeConfig("t", 16, 2, "train")
    b = SyntheticDataset(cfg, shape).batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_frontend_batches_have_embeds():
    cfg = get_smoke_config("qwen2_vl_7b")
    shape = ShapeConfig("t", 16, 2, "train")
    b = SyntheticDataset(cfg, shape).batch_at(0)
    assert "embeds" in b and b["embeds"].shape == (2, 16, cfg.d_model)
    assert "positions" in b and b["positions"].shape == (3, 2, 16)


def test_decode_batches_single_token():
    cfg = get_smoke_config("olmo_1b")
    shape = ShapeConfig("t", 1024, 4, "decode")
    b = SyntheticDataset(cfg, shape).batch_at(0)
    assert b["tokens"].shape == (4, 1)
    assert (b["tokens"] < cfg.vocab_size).all()
