"""Serving path: batched prefill + continuous-batching decode loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import Request, ServeLoop
from repro.models import lm


@pytest.mark.parametrize("arch", ["granite_8b", "phi35_moe_42b",
                                  "xlstm_1_3b", "zamba2_7b",
                                  "musicgen_large"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.key(0))
    b, s, new = 2, 12, 3
    if cfg.frontend:
        from repro.models import frontend
        emb = frontend.synth_embeddings(cfg, b, s + new, jax.random.key(1))
        full = {"embeds": emb}
        prompt = {"embeds": emb[:, :s]}
        steps = [{"embeds": emb[:, s + i:s + i + 1]} for i in range(new)]
    else:
        toks = jax.random.randint(jax.random.key(1), (b, s + new), 0,
                                  cfg.vocab_size)
        full = {"tokens": toks}
        prompt = {"tokens": toks[:, :s]}
        steps = [{"tokens": toks[:, s + i:s + i + 1]} for i in range(new)]

    logits_full, _ = lm.forward(params, cfg, full)
    lg, cache = lm.prefill(params, cfg, prompt, max_len=s + new)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]),
        np.asarray(logits_full[:, s - 1].astype(jnp.float32)), atol=2e-2)
    outs = []
    for st in steps:
        lg2, cache = lm.decode_step(params, cfg, cache, st)
        outs.append(lg2)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)),
        np.asarray(logits_full[:, s:].astype(jnp.float32)), atol=2e-2)


@pytest.mark.parametrize("arch", ["olmo_1b", "zamba2_7b"])
def test_serve_loop_matches_greedy(arch):
    cfg = get_smoke_config(arch)
    loop = ServeLoop(cfg, batch=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(3)]
    for r, pr in enumerate(prompts):
        loop.submit(Request(r, pr, max_new=4))
    loop.drain()
    assert len(loop.done) == 3

    for rid in (0, 2):
        toks = list(prompts[rid])
        for _ in range(4):
            lg, _ = lm.forward(loop.params, cfg,
                               {"tokens": jnp.asarray(np.asarray(toks)[None])})
            toks.append(int(np.argmax(np.asarray(lg)[0, -1])))
        ref = toks[len(prompts[rid]):]
        got = [r for r in loop.done if r.rid == rid][0].out
        assert got == ref, (arch, rid, got, ref)
