"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp ref."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dft_matmul import fft_four_step, fft_four_step_ref
from repro.kernels.transpose import transpose, transpose_ref
from repro.kernels.twiddle import complex_multiply, complex_multiply_ref

RNG = np.random.default_rng(2)


def _pair(shape):
    return (jnp.asarray(RNG.standard_normal(shape), jnp.float32),
            jnp.asarray(RNG.standard_normal(shape), jnp.float32))


@pytest.mark.parametrize("factors", [(8, 8), (16, 16), (16, 32), (32, 64),
                                     (128, 128), (8, 128), (128, 8)])
@pytest.mark.parametrize("batch", [1, 5, 16])
def test_dft_matmul_shapes(factors, batch):
    n = factors[0] * factors[1]
    x = _pair((batch, n))
    k = fft_four_step(x, factors)
    r = fft_four_step_ref(x, factors)
    scale = float(jnp.max(jnp.abs(r[0]))) + 1e-6
    np.testing.assert_allclose(np.asarray(k[0]), np.asarray(r[0]),
                               atol=1e-4 * scale)
    np.testing.assert_allclose(np.asarray(k[1]), np.asarray(r[1]),
                               atol=1e-4 * scale)


@pytest.mark.parametrize("karatsuba", [False, True])
@pytest.mark.parametrize("permuted", [False, True])
def test_dft_matmul_modes(karatsuba, permuted):
    x = _pair((4, 1024))
    k = fft_four_step(x, (32, 32), karatsuba=karatsuba, permuted=permuted)
    r = fft_four_step_ref(x, (32, 32), karatsuba=karatsuba, permuted=permuted)
    scale = float(jnp.max(jnp.abs(r[0]))) + 1e-6
    np.testing.assert_allclose(np.asarray(k[0]), np.asarray(r[0]),
                               atol=1e-4 * scale)


@pytest.mark.parametrize("block_rows", [1, 3, 8])
def test_dft_matmul_block_rows(block_rows):
    x = _pair((6, 256))
    k = fft_four_step(x, (16, 16), block_rows=block_rows)
    r = fft_four_step_ref(x, (16, 16))
    np.testing.assert_allclose(np.asarray(k[0]), np.asarray(r[0]), atol=1e-3)


def test_dft_matmul_batched_nd():
    x = _pair((2, 3, 256))
    k = fft_four_step(x, (16, 16))
    r = fft_four_step_ref(x, (16, 16))
    np.testing.assert_allclose(np.asarray(k[0]), np.asarray(r[0]), atol=1e-3)


@pytest.mark.parametrize("shape", [(16, 16), (64, 128), (128, 64),
                                   (3, 40, 56), (2, 2, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_transpose_sweep(shape, dtype):
    if dtype == jnp.int32:
        x = jnp.asarray(RNG.integers(0, 100, shape), dtype)
    else:
        x = jnp.asarray(RNG.standard_normal(shape), dtype)
    np.testing.assert_array_equal(np.asarray(transpose(x)),
                                  np.asarray(transpose_ref(x)))


@pytest.mark.parametrize("block", [8, 32, 128])
def test_transpose_blocks(block):
    x = jnp.asarray(RNG.standard_normal((96, 160)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(transpose(x, block=block)),
                                  np.asarray(transpose_ref(x)))


@pytest.mark.parametrize("shape", [(128,), (4, 300), (2, 3, 64)])
def test_twiddle_sweep(shape):
    a = _pair(shape)
    b = _pair(shape)
    k = complex_multiply(a, b)
    r = complex_multiply_ref(a, b)
    np.testing.assert_allclose(np.asarray(k[0]), np.asarray(r[0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(k[1]), np.asarray(r[1]), atol=1e-5)


def test_twiddle_broadcast():
    a = _pair((4, 300))
    b = _pair((300,))
    k = complex_multiply(a, b)
    bb = (jnp.broadcast_to(b[0], a[0].shape), jnp.broadcast_to(b[1], a[1].shape))
    r = complex_multiply_ref(a, bb)
    np.testing.assert_allclose(np.asarray(k[0]), np.asarray(r[0]), atol=1e-5)
