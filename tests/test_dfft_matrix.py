"""Parametrized distributed-transform matrix (fast, degenerate meshes).

Every pencil transform x every comm spec shape (explicit backends, chunked
pipelining, auto, measure, per-axis sequences/dicts) must round-trip and
match the numpy oracle.  On a 1-device mesh all exchanges degenerate to the
identity, so this runs in the tier-1 fast path and locks the *plumbing*:
spec resolution, measure/auto substitution, padded-half cropping, and the
``comm`` argument actually reaching every exchange.  The same matrix runs
on a real 8-device mesh in tests/_dist_worker.py.
"""

import warnings

import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import api, comm, dfft, fftconv, plan

RNG = np.random.default_rng(11)

# the historical entry points stay under test (they are shims now); don't
# let their one-per-process DeprecationWarning clutter the run
warnings.filterwarnings("ignore", category=DeprecationWarning,
                        module=r"repro\.core\.dfft")
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

COMM_SPECS = ["collective", "pipelined", "pipelined:2", "agas", "auto",
              "measure"]
PER_AXIS_SPECS = [("pipelined", "collective"), ("measure", "collective"),
                  ("auto", "measure"), {"my": "agas"}, {"mx": "measure"}]


@pytest.fixture(scope="module")
def planner():
    return plan.Planner(backends=("jnp",))


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("fft",))


@pytest.fixture(scope="module")
def mesh2():
    return jax.make_mesh((1, 1), ("mx", "my"))


def _pencil_pair(mesh2, x):
    sh = NamedSharding(mesh2, P("mx", "my", None))
    return (jax.device_put(np.real(x).astype(np.float32), sh),
            jax.device_put(np.imag(x).astype(np.float32), sh))


@pytest.mark.parametrize("spec", COMM_SPECS + PER_AXIS_SPECS)
def test_fft3_ifft3_pencil_matrix(planner, mesh2, spec):
    x = (RNG.standard_normal((8, 8, 16))
         + 1j * RNG.standard_normal((8, 8, 16))).astype(np.complex64)
    pair = _pencil_pair(mesh2, x)
    rr, ri = dfft.fft3_pencil(pair, mesh2, ("mx", "my"), planner, comm=spec)
    ref = np.fft.fftn(x)
    err = np.max(np.abs((np.asarray(rr) + 1j * np.asarray(ri)) - ref)) \
        / np.max(np.abs(ref))
    assert err < 1e-4, spec
    br, bi = dfft.ifft3_pencil((rr, ri), mesh2, ("mx", "my"), planner,
                               comm=spec)
    back = np.asarray(br) + 1j * np.asarray(bi)
    assert np.max(np.abs(back - x)) < 1e-3, spec


@pytest.mark.parametrize("spec", COMM_SPECS + PER_AXIS_SPECS)
def test_rfft3_irfft3_pencil_matrix(planner, mesh2, spec):
    nz = 16
    x = RNG.standard_normal((8, 8, nz)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh2, P("mx", "my", None)))
    re, im = dfft.rfft3_pencil(xs, mesh2, ("mx", "my"), planner, comm=spec)
    ref = np.fft.rfftn(x)
    z = (np.asarray(re)[..., :nz // 2 + 1]
         + 1j * np.asarray(im)[..., :nz // 2 + 1])
    assert np.max(np.abs(z - ref)) / np.max(np.abs(ref)) < 1e-4, spec
    back = dfft.irfft3_pencil((re, im), mesh2, ("mx", "my"), nz, planner,
                              comm=spec)
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-3, spec


@pytest.mark.parametrize("spec", COMM_SPECS)
def test_fft2_ifft2_slab_matrix(planner, mesh1, spec):
    n, m = 16, 32
    x = RNG.standard_normal((n, m)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh1, P("fft", None)))
    c = dfft.fft2_slab(xs, mesh1, "fft", planner, comm=spec)
    z = np.asarray(c[0])[:, :m // 2 + 1] + 1j * np.asarray(c[1])[:, :m // 2 + 1]
    ref = np.fft.rfft2(x)
    assert np.max(np.abs(z - ref)) / np.max(np.abs(ref)) < 1e-4, spec
    back = dfft.ifft2_slab(c, mesh1, "fft", m, planner, comm=spec)
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-3, spec


@pytest.mark.parametrize("spec", ["collective", "pipelined:2", "agas",
                                  "auto", "measure"])
def test_fftconv_seq_sharded_matrix(planner, mesh1, spec):
    b, l, d = 2, 64, 4
    u = RNG.standard_normal((b, l, d)).astype(np.float32)
    k = RNG.standard_normal((d, l)).astype(np.float32)
    nf = 2 * l
    ref = np.fft.irfft(
        np.fft.rfft(np.pad(u, ((0, 0), (0, nf - l), (0, 0))), axis=1)
        * np.fft.rfft(np.pad(k.T[None], ((0, 0), (0, nf - l), (0, 0))),
                      axis=1),
        axis=1, n=nf)[:, :l, :]
    us = jax.device_put(u, NamedSharding(mesh1, P(None, "fft", None)))
    y = fftconv.fft_conv_seq_sharded(us, jax.numpy.asarray(k), mesh1, "fft",
                                     planner, comm=spec)
    assert np.max(np.abs(np.asarray(y) - ref)) / np.max(np.abs(ref)) \
        < 1e-3, spec


class _SpyBackend(comm.CommBackend):
    """Wraps collective, counting exchanges — proof the comm argument is
    honored rather than silently replaced by a default."""

    name = "spy"

    def __init__(self):
        self.inner = comm.CollectiveBackend()
        self.exchanges = 0

    def exchange(self, c, axis_name, *, split, concat, p):
        self.exchanges += 1
        return self.inner.exchange(c, axis_name, split=split, concat=concat,
                                   p=p)


def test_ifft2_slab_honors_comm_argument(planner, mesh1):
    """Regression for the PR-1 fix: ifft2_slab must route BOTH of its
    exchanges through the caller's backend (it once ignored ``comm``)."""
    n, m = 16, 32
    x = RNG.standard_normal((n, m)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh1, P("fft", None)))
    spy_f = _SpyBackend()
    c = dfft.fft2_slab(xs, mesh1, "fft", planner, comm=spy_f)
    assert spy_f.exchanges == 2
    spy_i = _SpyBackend()
    back = dfft.ifft2_slab(c, mesh1, "fft", m, planner, comm=spy_i)
    assert spy_i.exchanges == 2
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-3
    # transposed-spectrum variants skip exactly one exchange each
    spy_t = _SpyBackend()
    ct = dfft.fft2_slab(xs, mesh1, "fft", planner, comm=spy_t,
                        keep_transposed=True)
    assert spy_t.exchanges == 1
    spy_ti = _SpyBackend()
    dfft.ifft2_slab(ct, mesh1, "fft", m, planner, comm=spy_ti,
                    from_transposed=True)
    assert spy_ti.exchanges == 1


def test_pencil_honors_per_axis_comm(planner, mesh2):
    """Each mesh axis's exchanges go through its own backend: forward and
    inverse pencil transforms touch each communicator exactly once."""
    x = (RNG.standard_normal((8, 8, 16))
         + 1j * RNG.standard_normal((8, 8, 16))).astype(np.complex64)
    pair = _pencil_pair(mesh2, x)
    s0, s1 = _SpyBackend(), _SpyBackend()
    c = dfft.fft3_pencil(pair, mesh2, ("mx", "my"), planner, comm=(s0, s1))
    assert (s0.exchanges, s1.exchanges) == (1, 1)
    dfft.ifft3_pencil(c, mesh2, ("mx", "my"), planner, comm=(s0, s1))
    assert (s0.exchanges, s1.exchanges) == (2, 2)


# ---------------------------------------------------------------------------
# the planned front-end matrix: every decomposition x kind x awkward shape
# (non-divisible axes, odd/prime lengths, leading batch dims).  Degenerate
# meshes lock the plumbing in the tier-1 fast path; the same recipes run on
# real 3- and 8-device meshes in tests/_dist_worker.py.
# ---------------------------------------------------------------------------

FFTN_SHAPES = [
    ((8, 16), ()),            # divisible, no batch
    ((10, 7), ()),            # non-divisible rows, odd/prime columns
    ((6, 10, 9), (2,)),       # batched 3D, nothing divides a 4x2 mesh
    ((5, 12), (2, 3)),        # two leading batch dims
    ((4, 6, 5, 8), (2,)),     # batched 4D: the multi-axis pencil chain
]


def _decomp_args(decomp, mesh1, mesh2):
    if decomp == "local":
        return None, None
    if decomp == "slab":
        return mesh1, ("fft",)
    return mesh2, ("mx", "my")


@pytest.mark.parametrize("shape,batch", FFTN_SHAPES)
@pytest.mark.parametrize("decomp", ["local", "slab", "pencil"])
def test_fftn_matrix(planner, mesh1, mesh2, decomp, shape, batch):
    if decomp == "pencil" and len(shape) < 3:
        pytest.skip("pencil decomposition needs ndim >= 3")
    if decomp == "slab" and len(shape) < 2:
        pytest.skip("slab decomposition needs ndim >= 2")
    mesh, axes = _decomp_args(decomp, mesh1, mesh2)
    x = (RNG.standard_normal(batch + shape)
         + 1j * RNG.standard_normal(batch + shape)).astype(np.complex64)
    nd = api.plan_nd(shape, "c2c", mesh=mesh, planner=planner,
                     decomp=decomp, axes=axes)
    re, im = api.fftn(x, mesh=mesh, plan=nd, planner=planner,
                      ndim=len(shape))
    ref = np.fft.fftn(x, axes=tuple(range(-len(shape), 0)))
    got = np.asarray(re) + 1j * np.asarray(im)
    assert got.shape == ref.shape
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-4
    br, bi = api.ifftn((re, im), mesh=mesh, plan=nd, planner=planner,
                       ndim=len(shape))
    back = np.asarray(br) + 1j * np.asarray(bi)
    assert back.shape == x.shape
    assert np.max(np.abs(back - x)) < 1e-3


@pytest.mark.parametrize("shape,batch", FFTN_SHAPES)
@pytest.mark.parametrize("decomp", ["local", "slab", "pencil"])
def test_rfftn_matrix(planner, mesh1, mesh2, decomp, shape, batch):
    if decomp == "pencil" and len(shape) < 3:
        pytest.skip("pencil decomposition needs ndim >= 3")
    mesh, axes = _decomp_args(decomp, mesh1, mesh2)
    x = RNG.standard_normal(batch + shape).astype(np.float32)
    nd = api.plan_nd(shape, "r2c", mesh=mesh, planner=planner,
                     decomp=decomp, axes=axes)
    re, im = api.rfftn(x, mesh=mesh, plan=nd, planner=planner,
                       ndim=len(shape))
    ref = np.fft.rfftn(x, axes=tuple(range(-len(shape), 0)))
    got = np.asarray(re) + 1j * np.asarray(im)
    assert got.shape == ref.shape
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-4
    back = api.irfftn((re, im), shape=shape, mesh=mesh, plan=nd,
                      planner=planner)
    assert back.shape == x.shape
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-3


# ---------------------------------------------------------------------------
# factor1d (distributed 1D factor split) and planned transposed layouts:
# every comm spec shape through the degenerate mesh, like the rows above
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", COMM_SPECS)
def test_factor1d_matrix(planner, mesh1, spec):
    n = 64
    x = (RNG.standard_normal((2, n))
         + 1j * RNG.standard_normal((2, n))).astype(np.complex64)
    nd = api.plan_nd((n,), "c2c", mesh=mesh1, planner=planner,
                     decomp="factor1d", axes=("fft",), comm=spec)
    assert nd.factors and nd.factors[0] * nd.factors[1] == n
    assert all(s not in ("auto", "measure") for s in nd.comm)
    re, im = api.fftn(x, mesh=mesh1, plan=nd, planner=planner, ndim=1)
    ref = np.fft.fft(x, axis=-1)
    got = np.asarray(re) + 1j * np.asarray(im)
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-4, spec
    br, bi = api.ifftn((re, im), mesh=mesh1, plan=nd, planner=planner,
                       ndim=1)
    back = np.asarray(br) + 1j * np.asarray(bi)
    assert np.max(np.abs(back - x)) < 1e-3, spec


@pytest.mark.parametrize("shape,batch",
                         [((8, 16), ()), ((10, 7), (2,)),
                          ((6, 10, 9), (2,))])
def test_fftn_transposed_layout_matrix(planner, mesh1, shape, batch):
    """Planned transposed slab output: numpy-exact values, inverse without
    the restore exchange, mixed radix and batch dims included."""
    x = (RNG.standard_normal(batch + shape)
         + 1j * RNG.standard_normal(batch + shape)).astype(np.complex64)
    nd = api.plan_nd(shape, "c2c", mesh=mesh1, planner=planner,
                     decomp="slab", axes=("fft",),
                     output_layout="transposed")
    assert nd.output_layout == "transposed"
    re, im = api.fftn(x, mesh=mesh1, plan=nd, planner=planner,
                      ndim=len(shape))
    ref = np.fft.fftn(x, axes=tuple(range(-len(shape), 0)))
    got = np.asarray(re) + 1j * np.asarray(im)
    assert got.shape == ref.shape
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-4
    br, bi = api.ifftn((re, im), mesh=mesh1, plan=nd, planner=planner,
                       ndim=len(shape))
    back = np.asarray(br) + 1j * np.asarray(bi)
    assert np.max(np.abs(back - x)) < 1e-3
