"""Config plumbing: remat policy, serve profile, padded vocab."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.models.lm import padded_vocab


def test_padded_vocab_alignment():
    cfg = get_smoke_config("granite_3_2b")       # vocab 515 -> 768
    assert padded_vocab(cfg) % 256 == 0
    assert padded_vocab(cfg) >= cfg.vocab_size


def test_pad_columns_masked_in_logits():
    cfg = get_smoke_config("granite_3_2b")
    params = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    logits, _ = lm.forward(params, cfg, {"tokens": toks})
    pad_region = np.asarray(logits[..., cfg.vocab_size:], np.float32)
    assert (pad_region <= -1e29).all()


def test_remat_policy_changes_graph_not_values():
    cfg = get_smoke_config("granite_8b")
    cfg_dots = dataclasses.replace(cfg, remat_policy="dots")
    params = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    g1 = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    g2 = jax.grad(lambda p: lm.loss_fn(p, cfg_dots, batch)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_param_dtype_bf16_meta():
    cfg = dataclasses.replace(get_smoke_config("olmo_1b"),
                              param_dtype="bfloat16")
    from repro.models.params import abstract_tree
    leaves = jax.tree_util.tree_leaves(abstract_tree(lm.model_meta(cfg)))
    assert all(l.dtype == jnp.bfloat16 for l in leaves)


def test_reduce_dtype_numerics_close():
    cfg = get_smoke_config("granite_8b")
    cfg_bf = dataclasses.replace(cfg, reduce_dtype="bfloat16")
    params = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    l1 = float(lm.loss_fn(params, cfg, {"tokens": toks, "labels": toks})[0])
    l2 = float(lm.loss_fn(params, cfg_bf, {"tokens": toks, "labels": toks})[0])
    assert abs(l1 - l2) < 5e-2
