"""Hypothesis property tests on the FFT system's mathematical invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import algo  # noqa: E402

SIZES = st.sampled_from([8, 16, 32, 64, 128, 256, 512])
BATCH = st.integers(min_value=1, max_value=4)


def _signal(n, b, seed):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((b, n)).astype(np.float32)
            + 1j * rng.standard_normal((b, n)).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(n=SIZES, b=BATCH, seed=st.integers(0, 2 ** 20),
       alpha=st.floats(-3, 3), beta=st.floats(-3, 3))
def test_linearity(n, b, seed, alpha, beta):
    x = _signal(n, b, seed)
    y = _signal(n, b, seed + 1)
    lhs = algo.to_complex(algo.fft(algo.to_pair(alpha * x + beta * y)))
    rhs = (alpha * algo.to_complex(algo.fft(algo.to_pair(x)))
           + beta * algo.to_complex(algo.fft(algo.to_pair(y))))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               atol=1e-3 * n)


@settings(max_examples=25, deadline=None)
@given(n=SIZES, b=BATCH, seed=st.integers(0, 2 ** 20))
def test_parseval(n, b, seed):
    x = _signal(n, b, seed)
    fx = np.asarray(algo.to_complex(algo.fft(algo.to_pair(x))))
    np.testing.assert_allclose(np.sum(np.abs(fx) ** 2, -1),
                               n * np.sum(np.abs(x) ** 2, -1),
                               rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(n=SIZES, b=BATCH, seed=st.integers(0, 2 ** 20))
def test_inverse(n, b, seed):
    x = _signal(n, b, seed)
    back = np.asarray(algo.to_complex(algo.ifft(algo.fft(algo.to_pair(x)))))
    np.testing.assert_allclose(back, x, atol=1e-4 * n)


@settings(max_examples=20, deadline=None)
@given(n=SIZES, seed=st.integers(0, 2 ** 20), shift=st.integers(0, 63))
def test_shift_theorem(n, seed, shift):
    """FFT(roll(x, s))[k] == FFT(x)[k] * exp(-2 pi i k s / n)."""
    shift = shift % n
    x = _signal(n, 1, seed)
    fx = np.asarray(algo.to_complex(algo.fft(algo.to_pair(x))))
    fs = np.asarray(algo.to_complex(algo.fft(algo.to_pair(
        np.roll(x, shift, axis=-1)))))
    k = np.arange(n)
    phase = np.exp(-2j * np.pi * k * shift / n)
    np.testing.assert_allclose(fs, fx * phase, atol=2e-3 * n)


@settings(max_examples=20, deadline=None)
@given(n=SIZES, seed=st.integers(0, 2 ** 20))
def test_convolution_theorem(n, seed):
    """ifft(fft(x) * fft(h)) == circular_conv(x, h), incl permuted plans."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, n)).astype(np.float32)
    h = rng.standard_normal((1, n)).astype(np.float32)
    ref = np.real(np.fft.ifft(np.fft.fft(x) * np.fft.fft(h)))
    factors = algo.default_factorization(n)
    xp = algo.to_pair(x.astype(np.complex64))
    hp = algo.to_pair(h.astype(np.complex64))
    if len(factors) == 2:
        fx = algo.fft(xp, factors=factors, permuted=True)
        fh = algo.fft(hp, factors=factors, permuted=True)
        out = algo.ifft_from_permuted(algo.cmul(fx, fh), factors=factors)
    else:
        fx = algo.fft(xp)
        fh = algo.fft(hp)
        out = algo.ifft(algo.cmul(fx, fh))
    np.testing.assert_allclose(np.asarray(out[0]), ref, atol=2e-3 * n)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([16, 64, 256]), seed=st.integers(0, 2 ** 20))
def test_rfft_conjugate_symmetry_consistency(n, seed):
    """rfft equals fft of the real signal on the half spectrum."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2, n)).astype(np.float32)
    half = np.asarray(algo.to_complex(algo.rfft(x)))
    full = np.asarray(algo.to_complex(algo.fft(
        algo.to_pair(x.astype(np.complex64)))))
    np.testing.assert_allclose(half, full[..., :n // 2 + 1], atol=1e-3 * n)


# ---------------------------------------------------------------------------
# planning invariants: plans round-trip for every kind x backend, and wisdom
# survives serialization byte-identically
# ---------------------------------------------------------------------------

from repro.core import plan as plan_mod  # noqa: E402

PLAN_BACKENDS = st.sampled_from(plan_mod.BACKENDS)
PLAN_SIZES = st.sampled_from([16, 64, 256])


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(n=PLAN_SIZES, backend=PLAN_BACKENDS, seed=st.integers(0, 2 ** 20),
       b=st.integers(1, 3))
def test_plan_execute_roundtrip_c2c(n, backend, seed, b):
    """execute -> execute_inverse is the identity for every backend a Plan
    can hold (permuted pallas plans invert through ifft_from_permuted)."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((b, n))
         + 1j * rng.standard_normal((b, n))).astype(np.complex64)
    p = plan_mod.Planner(mode="estimate", backends=(backend,))
    pl = p.plan(n, "c2c", batch=b)
    back = plan_mod.execute_inverse(pl, plan_mod.execute(pl, algo.to_pair(x)))
    z = np.asarray(back[0]) + 1j * np.asarray(back[1])
    np.testing.assert_allclose(z, x, atol=2e-3 * max(np.abs(x).max(), 1))


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(n=PLAN_SIZES, backend=PLAN_BACKENDS, seed=st.integers(0, 2 ** 20))
def test_plan_execute_roundtrip_r2c_c2r(n, backend, seed):
    """The r2c/c2r plan pair round-trips real signals for every backend."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2, n)).astype(np.float32)
    p = plan_mod.Planner(mode="estimate", backends=(backend,))
    back = plan_mod.execute(p.plan(n, "c2r"),
                            plan_mod.execute(p.plan(n, "r2c"), x))
    np.testing.assert_allclose(np.asarray(back), x,
                               atol=2e-3 * max(np.abs(x).max(), 1))


# ---------------------------------------------------------------------------
# the planned N-D front-end: fftn/rfftn round-trip and match numpy over
# random shapes (odd/prime axis lengths and leading batch dims included) on
# every decomposition the mesh supports.  In the main pytest process the
# meshes are 1-device (the plumbing + pad-and-crop math); the same sweep
# runs on real 4- and 8-device CPU meshes in tests/_dist_worker.py.
# ---------------------------------------------------------------------------

import jax  # noqa: E402

from repro.core import api  # noqa: E402

AXIS_SIZES = st.sampled_from([4, 6, 7, 8, 9, 12, 13, 16])
N_BATCH = st.integers(min_value=0, max_value=2)
DECOMP = st.sampled_from(["local", "slab", "pencil"])


def _fftn_meshes():
    """1-, 4- and 8-device meshes, as the running process allows (pytest's
    main process sees 1 device; tests/_dist_worker.py re-runs with 8)."""
    n = len(jax.devices())
    out = {}
    for count, shape2 in ((1, (1, 1)), (4, (2, 2)), (8, (4, 2))):
        if count <= n:
            out[count] = (jax.make_mesh((count,), ("fft",)),
                          jax.make_mesh(shape2, ("mx", "my")))
    return out


_MESHES = _fftn_meshes()
_PLANNER = plan_mod.Planner(backends=("jnp",))


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(dims=st.lists(AXIS_SIZES, min_size=2, max_size=3),
       nb=N_BATCH, decomp=DECOMP, seed=st.integers(0, 2 ** 20),
       devices=st.sampled_from([1, 4, 8]))
def test_fftn_rfftn_roundtrip_matches_numpy(dims, nb, decomp, seed, devices):
    shape = tuple(dims)
    if decomp == "pencil" and len(shape) != 3:
        decomp = "slab"
    meshes = _MESHES.get(devices) or _MESHES[1]
    mesh, axes = ((meshes[0], ("fft",)) if decomp == "slab"
                  else (meshes[1], ("mx", "my")) if decomp == "pencil"
                  else (None, None))
    rng = np.random.default_rng(seed)
    batch = tuple(rng.integers(1, 3, size=nb))
    x = rng.standard_normal(batch + shape).astype(np.float32)
    tf_axes = tuple(range(-len(shape), 0))

    nd = api.plan_nd(shape, "r2c", mesh=mesh, planner=_PLANNER,
                     decomp=decomp, axes=axes)
    re, im = api.rfftn(x, mesh=mesh, plan=nd, planner=_PLANNER,
                       ndim=len(shape))
    ref = np.fft.rfftn(x, axes=tf_axes)
    got = np.asarray(re) + 1j * np.asarray(im)
    assert got.shape == ref.shape
    scale = max(np.max(np.abs(ref)), 1.0)
    np.testing.assert_allclose(got, ref, atol=2e-4 * scale * len(shape))
    back = api.irfftn((re, im), shape=shape, mesh=mesh, plan=nd,
                      planner=_PLANNER)
    np.testing.assert_allclose(np.asarray(back), x,
                               atol=2e-4 * scale)

    ndc = api.plan_nd(shape, "c2c", mesh=mesh, planner=_PLANNER,
                      decomp=decomp, axes=axes)
    cre, cim = api.fftn(x, mesh=mesh, plan=ndc, planner=_PLANNER,
                        ndim=len(shape))
    refc = np.fft.fftn(x, axes=tf_axes)
    gotc = np.asarray(cre) + 1j * np.asarray(cim)
    np.testing.assert_allclose(gotc, refc, atol=2e-4 * scale * len(shape))


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(ns=st.lists(PLAN_SIZES, min_size=1, max_size=3, unique=True),
       kind=st.sampled_from(["c2c", "r2c"]), b=st.integers(1, 64))
def test_measured_wisdom_export_import_byte_identical(ns, kind, b):
    """Measured wisdom survives an export -> import cycle byte-identically,
    whatever mix of sizes/kinds/batch buckets was planned."""
    p = plan_mod.Planner(mode="measured", backends=("jnp", "xla_native"),
                         hardware=plan_mod.CPU_LOCAL)
    for n in ns:
        p.plan(n, kind, batch=b)
    text = p.export_wisdom()
    q = plan_mod.Planner(mode="measured", backends=("jnp", "xla_native"),
                         hardware=plan_mod.CPU_LOCAL)
    assert q.import_wisdom(text) == len(ns)
    assert q.export_wisdom() == text
    for n in ns:                      # imported wisdom fully serves plans
        q.plan(n, kind, batch=b)
        assert q.last_plan_seconds == 0.0
