"""Fused FFT-convolution Pallas kernel vs jnp.fft oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fftconv import fftconv_fused, fftconv_fused_ref

RNG = np.random.default_rng(12)


@pytest.mark.parametrize("factors", [(8, 8), (16, 16), (16, 32), (32, 64),
                                     (64, 64)])
@pytest.mark.parametrize("batch", [1, 6, 16])
def test_fftconv_fused_sweep(factors, batch):
    nf = factors[0] * factors[1]
    x = jnp.asarray(RNG.standard_normal((batch, nf)), jnp.float32)
    h = jnp.asarray(RNG.standard_normal(nf)
                    * np.exp(-np.arange(nf) / 64), jnp.float32)
    got = fftconv_fused(x, h, factors)
    ref = fftconv_fused_ref(x, h)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-4 * scale)


@pytest.mark.parametrize("block_rows", [1, 4, 8])
def test_fftconv_fused_block_rows(block_rows):
    x = jnp.asarray(RNG.standard_normal((8, 256)), jnp.float32)
    h = jnp.asarray(RNG.standard_normal(256), jnp.float32)
    got = fftconv_fused(x, h, (16, 16), block_rows=block_rows)
    ref = fftconv_fused_ref(x, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-2)


def test_fftconv_causal_via_padding():
    """Causal conv = circular conv on 2x padded signals (how the LM uses it)."""
    l = 128
    x = RNG.standard_normal((2, l)).astype(np.float32)
    h = (RNG.standard_normal(l) * np.exp(-np.arange(l) / 16)).astype(np.float32)
    xp = jnp.asarray(np.pad(x, ((0, 0), (0, l))))
    hp = jnp.asarray(np.pad(h, (0, l)))
    got = np.asarray(fftconv_fused(xp, hp, (16, 16)))[:, :l]
    ref = np.stack([np.convolve(x[i], h)[:l] for i in range(2)])
    np.testing.assert_allclose(got, ref, atol=1e-3 * np.abs(ref).max())
