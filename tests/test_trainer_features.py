"""Trainer features: gradient accumulation equivalence, frontend-arch E2E,
comm planning, scatter/collect."""

import jax
import numpy as np

from repro.models.config import ArchConfig, ShapeConfig
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig

ARCH = ArchConfig("tiny", "dense", 2, 64, 4, 2, 128, 256,
                  compute_dtype="float32")
OPT = AdamWConfig(warmup_steps=2, total_steps=50)


def test_grad_accum_matches_full_batch(tmp_path):
    shape = ShapeConfig("t", 32, 8, "train")
    t1 = Trainer(ARCH, shape, None,
                 TrainerConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=100,
                               grad_accum=1), OPT)
    t4 = Trainer(ARCH, shape, None,
                 TrainerConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=100,
                               grad_accum=4), OPT)
    p1, _, h1 = t1.run(3)
    p4, _, h4 = t4.run(3)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    assert abs(h1[-1]["loss"] - h4[-1]["loss"]) < 1e-3


def test_frontend_arch_trains_end_to_end(tmp_path):
    """qwen2-vl smoke (embeds input + mrope positions) through the Trainer."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen2_vl_7b")
    shape = ShapeConfig("t", 16, 4, "train")
    tr = Trainer(cfg, shape, None,
                 TrainerConfig(ckpt_dir=str(tmp_path / "v"), ckpt_every=100),
                 OPT)
    _, _, hist = tr.run(2)
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_plan_comm_model():
    from repro.core.dfft import plan_comm
    from repro.core.plan import HardwareSpec
    # huge link bandwidth -> communication trivial -> monolithic collective
    fast_link = HardwareSpec("x", flops=1e14, hbm_bw=1e12, link_bw=1e13,
                             matmul_dim=128, vmem_bytes=1 << 27)
    assert plan_comm(1 << 14, 1 << 14, 256, hw=fast_link) == "collective"
    # starved link -> overlap pays
    slow_link = HardwareSpec("y", flops=1e15, hbm_bw=1e12, link_bw=1e8,
                             matmul_dim=128, vmem_bytes=1 << 27)
    assert plan_comm(1 << 14, 1 << 14, 256, hw=slow_link) == "pipelined"
    assert plan_comm(1 << 14, 1 << 14, 256, hw=slow_link,
                     overlap_capable=False) == "collective"


def test_scatter_collect_roundtrip():
    from repro.core.dfft import collect, distribute
    mesh = jax.make_mesh((1,), ("fft",))
    x = np.arange(64, dtype=np.float32).reshape(8, 8)
    xs = distribute(x, mesh, "fft")
    back = collect(xs)
    np.testing.assert_array_equal(back, x)
