"""End-to-end behaviour tests for the whole system."""

import jax
import numpy as np

from repro.models.config import ArchConfig, ShapeConfig
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def test_train_loss_decreases(tmp_path):
    """A tiny LM memorizes a repeating synthetic stream."""
    arch = ArchConfig("tiny", "dense", 2, 64, 4, 2, 128, 64,
                      compute_dtype="float32")

    class RepeatData:
        def __init__(self, inner):
            self.inner = inner

        def batch_at(self, step):
            return self.inner.batch_at(0)     # same batch every step

    shape = ShapeConfig("mem", 32, 4, "train")
    tr = Trainer(arch, shape, None,
                 TrainerConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=100),
                 AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    tr.data = RepeatData(tr.data)
    _, _, hist = tr.run(40)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7, \
        (hist[0]["loss"], hist[-1]["loss"])
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_serve_loop_generates():
    from repro.launch.serve import Request, ServeLoop
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("olmo_1b")
    loop = ServeLoop(cfg, batch=2, max_len=64)
    rng = np.random.default_rng(0)
    for r in range(4):
        loop.submit(Request(r, rng.integers(0, cfg.vocab_size, 4,
                                            ).astype(np.int32), max_new=6))
    loop.drain()
    assert len(loop.done) == 4
    assert all(len(r.out) == 6 for r in loop.done)
    assert all(0 <= t < cfg.vocab_size for r in loop.done for t in r.out)


def test_fft_app_end_to_end():
    """The paper's application: distributed-capable 2D FFT through the
    public API, against numpy."""
    from repro.core import Planner, run_variant
    rng = np.random.default_rng(3)
    x = rng.standard_normal((64, 128)).astype(np.float32)
    planner = Planner(mode="estimate", backends=("jnp",))
    out = run_variant("for_loop", x, planner)
    ref = np.fft.rfft2(x)
    z = np.asarray(out[0]) + 1j * np.asarray(out[1])
    np.testing.assert_allclose(z, ref, rtol=2e-4,
                               atol=2e-4 * np.abs(ref).max())
