"""Unit tests for the planned N-D front-end (repro.core.api).

Decomposition scoring, mesh-axis assignment, NdPlan padding/cropping
properties, dfft/* wisdom caching, and the deprecated-shim contract all run
on abstract or 1-device meshes, so this is tier-1-fast; the live 8-device
acceptance matrix runs in tests/_dist_worker.py.
"""

import warnings

import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import api, dfft, plan

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def planner():
    return plan.Planner(backends=("jnp",))


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("fft",))


@pytest.fixture(scope="module")
def mesh2():
    return jax.make_mesh((1, 1), ("mx", "my"))


@pytest.fixture(scope="module")
def mesh3():
    return jax.make_mesh((1, 1, 1), ("ma", "mb", "mc"))


# ---------------------------------------------------------------------------
# decomposition planning (abstract meshes: pure roofline, no devices needed)
# ---------------------------------------------------------------------------


def test_plan_nd_local_for_small_slab_for_large(planner):
    small = api.plan_nd((64, 64), "r2c", mesh={"fft": 8}, planner=planner)
    assert small.decomp == "local"
    large = api.plan_nd((1024, 1024), "r2c", mesh={"fft": 8},
                        planner=planner)
    assert large.decomp == "slab" and large.mesh_axes == ("fft",)
    assert large.est_cost < api.plan_nd(
        (1024, 1024), "r2c", mesh={"fft": 8}, planner=planner,
        decomp="local").est_cost


def test_plan_nd_pencil_for_large_3d(planner):
    nd = api.plan_nd((128, 128, 128), "c2c", mesh={"mx": 4, "my": 2},
                     planner=planner)
    assert nd.decomp == "pencil"
    assert set(nd.mesh_axes) == {"mx", "my"}
    assert len(nd.comm) == 2


def test_plan_nd_no_mesh_is_local(planner):
    nd = api.plan_nd((256, 256), "c2c", planner=planner)
    assert nd.decomp == "local" and nd.mesh_axes == () and nd.comm == ()


def test_plan_nd_mesh_axis_assignment_minimizes_padding(planner):
    # X=10 pads to 12 over p0=4 but to 10 over p0=2: the planner must
    # notice that assignment changes the padded byte count
    nd = api.plan_nd((10, 16, 2048), "c2c", mesh={"mx": 4, "my": 2},
                     planner=planner, comm="collective")
    if nd.decomp == "pencil":
        a, b = nd.padded_spectrum_shape, nd.shape
        alt = api.plan_nd((10, 16, 2048), "c2c", mesh={"mx": 4, "my": 2},
                          planner=planner, decomp="pencil",
                          axes=tuple(reversed(nd.mesh_axes)))
        assert np.prod(a) <= np.prod(alt.padded_spectrum_shape), (nd, alt)


def test_plan_nd_small_1d_stays_local(planner):
    nd = api.plan_nd((4096,), "c2c", mesh={"fft": 8}, planner=planner)
    assert nd.decomp == "local"


def test_plan_nd_large_1d_picks_factor_split(planner):
    """The distributed-1D factor-split candidate beats gather-local once
    the gathered bytes dominate the three exchange latencies."""
    nd = api.plan_nd((1 << 20,), "c2c", mesh={"fft": 8}, planner=planner)
    assert nd.decomp == "factor1d" and nd.mesh_axes == ("fft",)
    n1, n2 = nd.factors
    assert n1 * n2 == 1 << 20 and n1 % 8 == 0 and n2 % 8 == 0
    assert nd.est_cost < api.plan_nd((1 << 20,), "c2c", mesh={"fft": 8},
                                     planner=planner,
                                     decomp="local").est_cost


def test_plan_nd_factor1d_infeasible_split_not_enumerated(planner):
    # n not divisible by p**2: no factor split exists, local is the only
    # distributed-1D option (and r2c 1D never enumerates factor1d)
    assert ("factor1d", ("fft",)) not in api._candidates(
        (1 << 20,), "c2c", {"fft": 7})
    assert all(dec != "factor1d" for dec, _ in api._candidates(
        (1 << 20,), "r2c", {"fft": 8}))


def test_plan_nd_4d_enumerates_multi_axis_pencil(planner):
    """ndim > 3 pencil: the candidate space holds ordered mesh-axis tuples
    of every length 2..ndim-1 that the mesh supports."""
    cands = api._candidates((8, 8, 8, 8), "c2c", {"ma": 2, "mb": 2, "mc": 2})
    pencil = [axes for dec, axes in cands if dec == "pencil"]
    assert (("ma", "mb") in pencil and ("mb", "ma") in pencil
            and ("ma", "mb", "mc") in pencil)
    assert all(2 <= len(a) <= 3 for a in pencil)
    # 2-axis mesh, 4D shape: the pair candidates exist (ISSUE acceptance)
    pencil2 = [axes for dec, axes in api._candidates(
        (8, 8, 8, 8), "c2c", {"mx": 4, "my": 2}) if dec == "pencil"]
    assert ("mx", "my") in pencil2 and ("my", "mx") in pencil2


def test_ndplan_4d_pencil_padding_chain(planner):
    """Axis j (0 < j < k) is input-sharded over p_j and exchange-split over
    p_{j-1}: its padding must divide both communicators."""
    nd = api.plan_nd((10, 6, 7, 9), "c2c", mesh={"ma": 4, "mb": 3, "mc": 2},
                     planner=planner, decomp="pencil",
                     axes=("ma", "mb", "mc"))
    xp, yp, zp, wp = nd.padded_spectrum_shape
    assert xp % 4 == 0                      # p0
    assert yp % 4 == 0 and yp % 3 == 0      # lcm(p0, p1)
    assert zp % 3 == 0 and zp % 2 == 0      # lcm(p1, p2)
    assert wp % 2 == 0                      # p_{k-1}
    assert nd.crop == tuple(slice(0, n) for n in (10, 6, 7, 9))


# ---------------------------------------------------------------------------
# NdPlan padding / cropping properties (the shared pad-and-crop contract)
# ---------------------------------------------------------------------------


def test_ndplan_crop_and_padding_mixed_radix(planner):
    nd = api.plan_nd((10, 12), "r2c", mesh={"s": 3}, planner=planner,
                     decomp="slab", axes=("s",))
    assert nd.spectrum_shape == (10, 7)
    assert nd.padded_spectrum_shape == (12, 9)      # both padded to mult 3
    assert nd.padded_input_shape == (12, 12)
    assert nd.crop == (slice(0, 10), slice(0, 7))


def test_ndplan_pencil_y_padding_divides_both_communicators(planner):
    nd = api.plan_nd((8, 6, 16), "c2c", mesh={"mx": 4, "my": 3},
                     planner=planner, decomp="pencil", axes=("mx", "my"))
    xp, yp, zp = nd.padded_spectrum_shape
    assert yp % 4 == 0 and yp % 3 == 0              # lcm, not sequential pad
    assert xp % 4 == 0 and zp % 3 == 0


def test_collect_crops_via_plan(planner, mesh1):
    nd = api.plan_nd((6, 10), "r2c", mesh=mesh1, planner=planner,
                     decomp="slab", axes=("fft",))
    x = RNG.standard_normal((6, 10)).astype(np.float32)
    padded = api.execute_nd(nd, x, mesh=mesh1, planner=planner)
    re, im = dfft.collect(padded, nd)
    ref = np.fft.rfftn(x)
    assert re.shape == ref.shape
    np.testing.assert_allclose(re + 1j * im, ref, atol=1e-4)


# ---------------------------------------------------------------------------
# dfft/* wisdom caching
# ---------------------------------------------------------------------------


def test_plan_nd_verdict_cached_in_wisdom(planner):
    before = len(list(planner.wisdom.keys("dfft/")))
    nd = api.plan_nd((96, 320), "r2c", mesh={"fft": 8}, planner=planner)
    keys = list(planner.wisdom.keys("dfft/"))
    assert len(keys) == before + 1
    rec = planner.wisdom.get(
        "dfft/v2/96x320/r2c/fft8/estimate/auto/natural")
    assert rec is not None and rec["decomp"] == nd.decomp
    assert rec["output_layout"] == "natural" and rec["factors"] == []
    # a second call reconstructs the identical plan from the record
    nd2 = api.plan_nd((96, 320), "r2c", mesh={"fft": 8}, planner=planner)
    assert nd2 == nd
    # the layout is part of the key: a transposed plan caches separately
    ndt = api.plan_nd((96, 320), "r2c", mesh={"fft": 8}, planner=planner,
                      output_layout="transposed")
    assert ndt.output_layout == "transposed"
    assert planner.wisdom.get(
        "dfft/v2/96x320/r2c/fft8/estimate/auto/transposed") is not None


def test_plan_nd_migrates_v1_wisdom_schema(planner):
    """A pre-bump ``dfft/*`` record (no output_layout/factors fields) is
    adopted for natural-layout lookups and re-written under the v2 key."""
    v1_key = "dfft/70x130/r2c/fft8/estimate/auto"
    v2_key = "dfft/v2/70x130/r2c/fft8/estimate/auto/natural"
    planner.wisdom.put(v1_key, {
        "decomp": "slab", "mesh_axes": ["fft"], "mesh_shape": [8],
        "comm": ["collective"], "est": 2.5e-5, "measured": -1.0})
    nd = api.plan_nd((70, 130), "r2c", mesh={"fft": 8}, planner=planner)
    assert nd.decomp == "slab" and nd.comm == ("collective",)
    assert nd.output_layout == "natural" and nd.factors == ()
    assert nd.est_cost == 2.5e-5            # the v1 verdict, not a re-plan
    migrated = planner.wisdom.get(v2_key)
    assert migrated is not None and migrated["output_layout"] == "natural"
    # transposed lookups never adopt a v1 (implicitly natural) verdict
    ndt = api.plan_nd((70, 130), "r2c", mesh={"fft": 8}, planner=planner,
                      output_layout="transposed")
    assert ndt.output_layout == "transposed"


def test_plan_nd_ignores_corrupt_v1_record(planner):
    planner.wisdom.put("dfft/66x66/r2c/fft8/estimate/auto",
                       {"decomp": "warp-drive"})
    nd = api.plan_nd((66, 66), "r2c", mesh={"fft": 8}, planner=planner)
    assert nd.decomp in api.DECOMPS        # re-planned, not adopted
    # truncated v1 record (valid decomp, missing the list fields): also
    # re-planned rather than crashing the hit-reconstruction path
    planner.wisdom.put("dfft/68x68/r2c/fft8/estimate/auto",
                       {"decomp": "slab"})
    nd2 = api.plan_nd((68, 68), "r2c", mesh={"fft": 8}, planner=planner)
    assert nd2.decomp in api.DECOMPS and len(nd2.comm) == len(nd2.mesh_axes)


def test_plan_nd_heals_corrupt_v2_record(planner):
    """A truncated v2 record re-plans instead of KeyError-ing, and the
    fresh verdict overwrites the corruption."""
    key = "dfft/v2/44x44/r2c/fft8/estimate/auto/natural"
    planner.wisdom.put(key, {"decomp": "slab"})
    nd = api.plan_nd((44, 44), "r2c", mesh={"fft": 8}, planner=planner)
    assert nd.decomp in api.DECOMPS
    healed = planner.wisdom.get(key)
    assert isinstance(healed.get("mesh_axes"), list)    # overwritten
    # a factor1d record without its (n1, n2) split is equally untrusted
    key1 = "dfft/v2/1048576/c2c/fft8/estimate/auto/natural"
    planner.wisdom.put(key1, {"decomp": "factor1d", "mesh_axes": ["fft"],
                              "mesh_shape": [8], "comm": ["collective"]})
    nd1 = api.plan_nd((1 << 20,), "c2c", mesh={"fft": 8}, planner=planner)
    assert nd1.decomp != "factor1d" or len(nd1.factors) == 2


def test_plan_nd_instance_comm_not_cached(planner):
    from repro.core.comm import CollectiveBackend
    before = len(list(planner.wisdom.keys("dfft/")))
    api.plan_nd((64, 128), "c2c", mesh={"fft": 8}, planner=planner,
                comm=CollectiveBackend())
    assert len(list(planner.wisdom.keys("dfft/"))) == before


# ---------------------------------------------------------------------------
# deprecated shims: old entry points build NdPlans, warn once, match new
# ---------------------------------------------------------------------------


def test_fft2_slab_shim_matches_front_end(planner, mesh1):
    n, m = 16, 32
    x = RNG.standard_normal((n, m)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh1, P("fft", None)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = dfft.fft2_slab(xs, mesh1, "fft", planner)
    nd = api.plan_nd((n, m), "r2c", mesh=mesh1, planner=planner,
                     decomp="slab", axes=("fft",), comm="collective")
    new = api.execute_nd(nd, xs, mesh=mesh1, planner=planner)
    np.testing.assert_array_equal(np.asarray(old[0]), np.asarray(new[0]))
    np.testing.assert_array_equal(np.asarray(old[1]), np.asarray(new[1]))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        back = dfft.ifft2_slab(old, mesh1, "fft", m, planner)
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-3


def test_pencil_shim_matches_front_end(planner, mesh2):
    x = (RNG.standard_normal((8, 8, 16))
         + 1j * RNG.standard_normal((8, 8, 16))).astype(np.complex64)
    pair = (jax.numpy.asarray(np.real(x).astype(np.float32)),
            jax.numpy.asarray(np.imag(x).astype(np.float32)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = dfft.fft3_pencil(pair, mesh2, ("mx", "my"), planner)
    nd = api.plan_nd((8, 8, 16), "c2c", mesh=mesh2, planner=planner,
                     decomp="pencil", axes=("mx", "my"), comm="collective")
    new = api.execute_nd(nd, pair, mesh=mesh2, planner=planner)
    np.testing.assert_array_equal(np.asarray(old[0]), np.asarray(new[0]))
    np.testing.assert_array_equal(np.asarray(old[1]), np.asarray(new[1]))


def _call_shim(name, planner, mesh1, mesh2):
    """Invoke one deprecated entry point with minimal valid arguments."""
    if name in ("fft2_slab", "ifft2_slab"):
        x = RNG.standard_normal((8, 16)).astype(np.float32)
        xs = jax.device_put(x, NamedSharding(mesh1, P("fft", None)))
        if name == "fft2_slab":
            return dfft.fft2_slab(xs, mesh1, "fft", planner)
        c = (jax.numpy.zeros((8, 16)), jax.numpy.zeros((8, 16)))
        return dfft.ifft2_slab(c, mesh1, "fft", 16, planner)
    pair = (jax.numpy.zeros((4, 4, 8)), jax.numpy.zeros((4, 4, 8)))
    if name == "fft3_pencil":
        return dfft.fft3_pencil(pair, mesh2, ("mx", "my"), planner)
    if name == "ifft3_pencil":
        return dfft.ifft3_pencil(pair, mesh2, ("mx", "my"), planner)
    if name == "rfft3_pencil":
        return dfft.rfft3_pencil(jax.numpy.zeros((4, 4, 8)), mesh2,
                                 ("mx", "my"), planner)
    assert name == "irfft3_pencil"
    c = (jax.numpy.zeros((4, 4, 5)), jax.numpy.zeros((4, 4, 5)))
    return dfft.irfft3_pencil(c, mesh2, ("mx", "my"), 8, planner)


@pytest.mark.parametrize("name", ["fft2_slab", "ifft2_slab", "fft3_pencil",
                                  "ifft3_pencil", "rfft3_pencil",
                                  "irfft3_pencil"])
def test_every_shim_warns_deprecation_once_per_process(planner, mesh1,
                                                       mesh2, name):
    """The once-per-process DeprecationWarning contract, per entry point:
    the FIRST call warns, every later call is silent."""
    dfft._DEPRECATED_EMITTED.discard(name)
    with pytest.warns(DeprecationWarning, match=f"{name} is deprecated"):
        _call_shim(name, planner, mesh1, mesh2)
    assert name in dfft._DEPRECATED_EMITTED
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _call_shim(name, planner, mesh1, mesh2)     # second call: silent


# ---------------------------------------------------------------------------
# front-end numerics on 1-device meshes (full matrix in tests/test_dfft_matrix)
# ---------------------------------------------------------------------------


def test_fftn_matches_numpy_all_decomps(planner, mesh1, mesh2):
    x = (RNG.standard_normal((6, 10, 9))
         + 1j * RNG.standard_normal((6, 10, 9))).astype(np.complex64)
    ref = np.fft.fftn(x)
    for decomp, mesh, axes in (("local", None, None),
                               ("slab", mesh1, ("fft",)),
                               ("pencil", mesh2, ("mx", "my"))):
        nd = api.plan_nd((6, 10, 9), "c2c", mesh=mesh, planner=planner,
                         decomp=decomp, axes=axes)
        re, im = api.fftn(x, mesh=mesh, plan=nd, planner=planner)
        err = np.max(np.abs((np.asarray(re) + 1j * np.asarray(im)) - ref)) \
            / np.max(np.abs(ref))
        assert err < 1e-4, decomp
        br, bi = api.ifftn((re, im), mesh=mesh, plan=nd, planner=planner)
        assert np.max(np.abs((np.asarray(br) + 1j * np.asarray(bi)) - x)) \
            < 1e-3, decomp


def test_fftn_4d_multi_axis_pencil(planner, mesh2, mesh3):
    """ndim > 3 pencil on degenerate meshes: the k=2 and k=3 exchange
    chains execute numpy-exactly (real 8-device runs in _dist_worker)."""
    x = (RNG.standard_normal((2, 4, 6, 5, 8))
         + 1j * RNG.standard_normal((2, 4, 6, 5, 8))).astype(np.complex64)
    ref = np.fft.fftn(x, axes=(-4, -3, -2, -1))
    for mesh, axes in ((mesh2, ("mx", "my")), (mesh3, ("ma", "mb", "mc"))):
        nd = api.plan_nd((4, 6, 5, 8), "c2c", mesh=mesh, planner=planner,
                         decomp="pencil", axes=axes)
        assert len(nd.mesh_axes) == len(axes)
        re, im = api.fftn(x, mesh=mesh, plan=nd, planner=planner, ndim=4)
        got = np.asarray(re) + 1j * np.asarray(im)
        assert got.shape == ref.shape
        err = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
        assert err < 1e-4, axes
        br, bi = api.ifftn((re, im), mesh=mesh, plan=nd, planner=planner,
                           ndim=4)
        back = np.asarray(br) + 1j * np.asarray(bi)
        assert np.max(np.abs(back - x)) < 1e-3, axes


def test_rfftn_4d_pencil(planner, mesh3):
    x = RNG.standard_normal((4, 6, 5, 12)).astype(np.float32)
    nd = api.plan_nd((4, 6, 5, 12), "r2c", mesh=mesh3, planner=planner,
                     decomp="pencil", axes=("ma", "mb", "mc"))
    re, im = api.rfftn(x, mesh=mesh3, plan=nd, planner=planner)
    ref = np.fft.rfftn(x)
    got = np.asarray(re) + 1j * np.asarray(im)
    assert got.shape == ref.shape
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-4
    back = api.irfftn((re, im), shape=(4, 6, 5, 12), mesh=mesh3, plan=nd,
                      planner=planner)
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-3


def test_fftn_factor1d_degenerate_mesh(planner, mesh1):
    """The factor-split executor's plumbing on a 1-device mesh (identity
    exchanges); the real 8-device run lives in _dist_worker."""
    n = 64
    x = (RNG.standard_normal((3, n))
         + 1j * RNG.standard_normal((3, n))).astype(np.complex64)
    nd = api.plan_nd((n,), "c2c", mesh=mesh1, planner=planner,
                     decomp="factor1d", axes=("fft",))
    assert nd.factors and nd.factors[0] * nd.factors[1] == n
    re, im = api.fftn(x, mesh=mesh1, plan=nd, planner=planner, ndim=1)
    ref = np.fft.fft(x, axis=-1)
    got = np.asarray(re) + 1j * np.asarray(im)
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-4
    br, bi = api.ifftn((re, im), mesh=mesh1, plan=nd, planner=planner,
                       ndim=1)
    back = np.asarray(br) + 1j * np.asarray(bi)
    assert np.max(np.abs(back - x)) < 1e-3


def test_transposed_layout_planned_and_round_trips(planner, mesh1):
    """Planned keep_transposed: scored as a saved exchange, executed
    without the restore shuffle, inverted by ifftn/irfftn from the
    transposed layout — mixed radix included (the historical 2D-only flag
    required divisibility; the planned layout does not)."""
    nat = api.plan_nd((1024, 1024), "r2c", mesh={"fft": 8}, planner=planner,
                      decomp="slab")
    tra = api.plan_nd((1024, 1024), "r2c", mesh={"fft": 8}, planner=planner,
                      decomp="slab", output_layout="transposed")
    assert tra.est_cost < nat.est_cost      # one exchange instead of two
    # mixed radix r2c round trip (10 rows on an 8-way axis would have been
    # rejected by the legacy keep_transposed flag)
    x = RNG.standard_normal((10, 12)).astype(np.float32)
    nd = api.plan_nd((10, 12), "r2c", mesh=mesh1, planner=planner,
                     decomp="slab", axes=("fft",),
                     output_layout="transposed")
    re, im = api.rfftn(x, mesh=mesh1, plan=nd, planner=planner)
    ref = np.fft.rfftn(x)
    got = np.asarray(re) + 1j * np.asarray(im)
    assert got.shape == ref.shape
    assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-4
    back = api.irfftn((re, im), shape=(10, 12), mesh=mesh1, plan=nd,
                      planner=planner)
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-3
    # 3D c2c batched through the same planned layout
    x3 = (RNG.standard_normal((2, 6, 5, 9))
          + 1j * RNG.standard_normal((2, 6, 5, 9))).astype(np.complex64)
    nd3 = api.plan_nd((6, 5, 9), "c2c", mesh=mesh1, planner=planner,
                      decomp="slab", axes=("fft",),
                      output_layout="transposed")
    re3, im3 = api.fftn(x3, mesh=mesh1, plan=nd3, planner=planner, ndim=3)
    ref3 = np.fft.fftn(x3, axes=(-3, -2, -1))
    got3 = np.asarray(re3) + 1j * np.asarray(im3)
    assert np.max(np.abs(got3 - ref3)) / np.max(np.abs(ref3)) < 1e-4
    b3 = api.ifftn((re3, im3), mesh=mesh1, plan=nd3, planner=planner,
                   ndim=3)
    back3 = np.asarray(b3[0]) + 1j * np.asarray(b3[1])
    assert np.max(np.abs(back3 - x3)) < 1e-3


def test_transposed_layout_forbids_factor1d(planner):
    with pytest.raises(ValueError, match="natural-order"):
        api.plan_nd((1 << 20,), "c2c", mesh={"fft": 8}, planner=planner,
                    decomp="factor1d", output_layout="transposed")
    nd = api.plan_nd((1 << 20,), "c2c", mesh={"fft": 8}, planner=planner,
                     output_layout="transposed")
    assert nd.decomp != "factor1d"          # excluded from the free choice


def test_rfftn_odd_and_batched(planner, mesh1):
    x = RNG.standard_normal((2, 3, 12, 15)).astype(np.float32)
    ref = np.fft.rfftn(x, axes=(-2, -1))
    nd = api.plan_nd((12, 15), "r2c", mesh=mesh1, planner=planner,
                     decomp="slab", axes=("fft",))
    re, im = api.rfftn(x, mesh=mesh1, plan=nd, planner=planner, ndim=2)
    err = np.max(np.abs((np.asarray(re) + 1j * np.asarray(im)) - ref)) \
        / np.max(np.abs(ref))
    assert err < 1e-4
    back = api.irfftn((re, im), shape=(12, 15), mesh=mesh1, plan=nd,
                      planner=planner)
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-3
