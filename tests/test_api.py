"""Unit tests for the planned N-D front-end (repro.core.api).

Decomposition scoring, mesh-axis assignment, NdPlan padding/cropping
properties, dfft/* wisdom caching, and the deprecated-shim contract all run
on abstract or 1-device meshes, so this is tier-1-fast; the live 8-device
acceptance matrix runs in tests/_dist_worker.py.
"""

import warnings

import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import api, dfft, plan

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def planner():
    return plan.Planner(backends=("jnp",))


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("fft",))


@pytest.fixture(scope="module")
def mesh2():
    return jax.make_mesh((1, 1), ("mx", "my"))


# ---------------------------------------------------------------------------
# decomposition planning (abstract meshes: pure roofline, no devices needed)
# ---------------------------------------------------------------------------


def test_plan_nd_local_for_small_slab_for_large(planner):
    small = api.plan_nd((64, 64), "r2c", mesh={"fft": 8}, planner=planner)
    assert small.decomp == "local"
    large = api.plan_nd((1024, 1024), "r2c", mesh={"fft": 8},
                        planner=planner)
    assert large.decomp == "slab" and large.mesh_axes == ("fft",)
    assert large.est_cost < api.plan_nd(
        (1024, 1024), "r2c", mesh={"fft": 8}, planner=planner,
        decomp="local").est_cost


def test_plan_nd_pencil_for_large_3d(planner):
    nd = api.plan_nd((128, 128, 128), "c2c", mesh={"mx": 4, "my": 2},
                     planner=planner)
    assert nd.decomp == "pencil"
    assert set(nd.mesh_axes) == {"mx", "my"}
    assert len(nd.comm) == 2


def test_plan_nd_no_mesh_is_local(planner):
    nd = api.plan_nd((256, 256), "c2c", planner=planner)
    assert nd.decomp == "local" and nd.mesh_axes == () and nd.comm == ()


def test_plan_nd_mesh_axis_assignment_minimizes_padding(planner):
    # X=10 pads to 12 over p0=4 but to 10 over p0=2: the planner must
    # notice that assignment changes the padded byte count
    nd = api.plan_nd((10, 16, 2048), "c2c", mesh={"mx": 4, "my": 2},
                     planner=planner, comm="collective")
    if nd.decomp == "pencil":
        a, b = nd.padded_spectrum_shape, nd.shape
        alt = api.plan_nd((10, 16, 2048), "c2c", mesh={"mx": 4, "my": 2},
                          planner=planner, decomp="pencil",
                          axes=tuple(reversed(nd.mesh_axes)))
        assert np.prod(a) <= np.prod(alt.padded_spectrum_shape), (nd, alt)


def test_plan_nd_1d_stays_local(planner):
    nd = api.plan_nd((4096,), "c2c", mesh={"fft": 8}, planner=planner)
    assert nd.decomp == "local"


# ---------------------------------------------------------------------------
# NdPlan padding / cropping properties (the shared pad-and-crop contract)
# ---------------------------------------------------------------------------


def test_ndplan_crop_and_padding_mixed_radix(planner):
    nd = api.plan_nd((10, 12), "r2c", mesh={"s": 3}, planner=planner,
                     decomp="slab", axes=("s",))
    assert nd.spectrum_shape == (10, 7)
    assert nd.padded_spectrum_shape == (12, 9)      # both padded to mult 3
    assert nd.padded_input_shape == (12, 12)
    assert nd.crop == (slice(0, 10), slice(0, 7))


def test_ndplan_pencil_y_padding_divides_both_communicators(planner):
    nd = api.plan_nd((8, 6, 16), "c2c", mesh={"mx": 4, "my": 3},
                     planner=planner, decomp="pencil", axes=("mx", "my"))
    xp, yp, zp = nd.padded_spectrum_shape
    assert yp % 4 == 0 and yp % 3 == 0              # lcm, not sequential pad
    assert xp % 4 == 0 and zp % 3 == 0


def test_collect_crops_via_plan(planner, mesh1):
    nd = api.plan_nd((6, 10), "r2c", mesh=mesh1, planner=planner,
                     decomp="slab", axes=("fft",))
    x = RNG.standard_normal((6, 10)).astype(np.float32)
    padded = api.execute_nd(nd, x, mesh=mesh1, planner=planner)
    re, im = dfft.collect(padded, nd)
    ref = np.fft.rfftn(x)
    assert re.shape == ref.shape
    np.testing.assert_allclose(re + 1j * im, ref, atol=1e-4)


# ---------------------------------------------------------------------------
# dfft/* wisdom caching
# ---------------------------------------------------------------------------


def test_plan_nd_verdict_cached_in_wisdom(planner):
    before = len(list(planner.wisdom.keys("dfft/")))
    nd = api.plan_nd((96, 320), "r2c", mesh={"fft": 8}, planner=planner)
    keys = list(planner.wisdom.keys("dfft/"))
    assert len(keys) == before + 1
    rec = planner.wisdom.get(
        "dfft/96x320/r2c/fft8/estimate/auto")
    assert rec is not None and rec["decomp"] == nd.decomp
    # a second call reconstructs the identical plan from the record
    nd2 = api.plan_nd((96, 320), "r2c", mesh={"fft": 8}, planner=planner)
    assert nd2 == nd


def test_plan_nd_instance_comm_not_cached(planner):
    from repro.core.comm import CollectiveBackend
    before = len(list(planner.wisdom.keys("dfft/")))
    api.plan_nd((64, 128), "c2c", mesh={"fft": 8}, planner=planner,
                comm=CollectiveBackend())
    assert len(list(planner.wisdom.keys("dfft/"))) == before


# ---------------------------------------------------------------------------
# deprecated shims: old entry points build NdPlans, warn once, match new
# ---------------------------------------------------------------------------


def test_fft2_slab_shim_matches_front_end(planner, mesh1):
    n, m = 16, 32
    x = RNG.standard_normal((n, m)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh1, P("fft", None)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = dfft.fft2_slab(xs, mesh1, "fft", planner)
    nd = api.plan_nd((n, m), "r2c", mesh=mesh1, planner=planner,
                     decomp="slab", axes=("fft",), comm="collective")
    new = api.execute_nd(nd, xs, mesh=mesh1, planner=planner)
    np.testing.assert_array_equal(np.asarray(old[0]), np.asarray(new[0]))
    np.testing.assert_array_equal(np.asarray(old[1]), np.asarray(new[1]))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        back = dfft.ifft2_slab(old, mesh1, "fft", m, planner)
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-3


def test_pencil_shim_matches_front_end(planner, mesh2):
    x = (RNG.standard_normal((8, 8, 16))
         + 1j * RNG.standard_normal((8, 8, 16))).astype(np.complex64)
    pair = (jax.numpy.asarray(np.real(x).astype(np.float32)),
            jax.numpy.asarray(np.imag(x).astype(np.float32)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = dfft.fft3_pencil(pair, mesh2, ("mx", "my"), planner)
    nd = api.plan_nd((8, 8, 16), "c2c", mesh=mesh2, planner=planner,
                     decomp="pencil", axes=("mx", "my"), comm="collective")
    new = api.execute_nd(nd, pair, mesh=mesh2, planner=planner)
    np.testing.assert_array_equal(np.asarray(old[0]), np.asarray(new[0]))
    np.testing.assert_array_equal(np.asarray(old[1]), np.asarray(new[1]))


def test_shims_warn_deprecation_once_per_process(planner, mesh1):
    dfft._DEPRECATED_EMITTED.discard("fft2_slab")
    x = RNG.standard_normal((8, 16)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh1, P("fft", None)))
    with pytest.warns(DeprecationWarning, match="fft2_slab is deprecated"):
        dfft.fft2_slab(xs, mesh1, "fft", planner)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        dfft.fft2_slab(xs, mesh1, "fft", planner)   # second call: silent


# ---------------------------------------------------------------------------
# front-end numerics on 1-device meshes (full matrix in tests/test_dfft_matrix)
# ---------------------------------------------------------------------------


def test_fftn_matches_numpy_all_decomps(planner, mesh1, mesh2):
    x = (RNG.standard_normal((6, 10, 9))
         + 1j * RNG.standard_normal((6, 10, 9))).astype(np.complex64)
    ref = np.fft.fftn(x)
    for decomp, mesh, axes in (("local", None, None),
                               ("slab", mesh1, ("fft",)),
                               ("pencil", mesh2, ("mx", "my"))):
        nd = api.plan_nd((6, 10, 9), "c2c", mesh=mesh, planner=planner,
                         decomp=decomp, axes=axes)
        re, im = api.fftn(x, mesh=mesh, plan=nd, planner=planner)
        err = np.max(np.abs((np.asarray(re) + 1j * np.asarray(im)) - ref)) \
            / np.max(np.abs(ref))
        assert err < 1e-4, decomp
        br, bi = api.ifftn((re, im), mesh=mesh, plan=nd, planner=planner)
        assert np.max(np.abs((np.asarray(br) + 1j * np.asarray(bi)) - x)) \
            < 1e-3, decomp


def test_rfftn_odd_and_batched(planner, mesh1):
    x = RNG.standard_normal((2, 3, 12, 15)).astype(np.float32)
    ref = np.fft.rfftn(x, axes=(-2, -1))
    nd = api.plan_nd((12, 15), "r2c", mesh=mesh1, planner=planner,
                     decomp="slab", axes=("fft",))
    re, im = api.rfftn(x, mesh=mesh1, plan=nd, planner=planner, ndim=2)
    err = np.max(np.abs((np.asarray(re) + 1j * np.asarray(im)) - ref)) \
        / np.max(np.abs(ref))
    assert err < 1e-4
    back = api.irfftn((re, im), shape=(12, 15), mesh=mesh1, plan=nd,
                      planner=planner)
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-3
