"""Unit tests for the comm-backend subsystem (single device, fast).

Exchange *numerics* across real multi-device meshes live in
tests/_dist_worker.py; here we cover spec resolution, the roofline
planners, and the degenerate p=1 exchange (which also smoke-tests the
jax.shard_map compat shim inside tier-1's fast path).
"""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import comm
from repro.core.compat import shard_map


def test_get_backend_resolution():
    assert isinstance(comm.get_backend("collective"), comm.CollectiveBackend)
    assert isinstance(comm.get_backend("agas"), comm.AgasBackend)
    b = comm.get_backend("pipelined", chunks=6)
    assert isinstance(b, comm.PipelinedBackend) and b.chunks == 6
    # inline chunk override spelling
    assert comm.get_backend("pipelined:8").chunks == 8
    # idempotent on instances
    assert comm.get_backend(b) is b
    with pytest.raises(ValueError):
        comm.get_backend("parcelport")
    with pytest.raises(TypeError):
        comm.get_backend(42)


def test_resolve_axis_backends():
    axes = ("mx", "my")
    # one spec fans out to every axis
    b = comm.resolve_axis_backends("pipelined", axes)
    assert [x.name for x in b] == ["pipelined", "pipelined"]
    # per-axis sequence, ordered as axes
    b = comm.resolve_axis_backends(("collective", "agas"), axes)
    assert [x.name for x in b] == ["collective", "agas"]
    # dict keyed by mesh-axis name; missing axes default to collective
    b = comm.resolve_axis_backends({"my": "pipelined:2"}, axes)
    assert [x.name for x in b] == ["collective", "pipelined"]
    assert b[1].chunks == 2
    with pytest.raises(ValueError):
        comm.resolve_axis_backends(("collective",), axes)
    # a typo'd mesh-axis key must not silently fall back to collective
    with pytest.raises(ValueError):
        comm.resolve_axis_backends({"mz": "agas"}, axes)


def test_plan_comm_pencil_model():
    from repro.core.plan import HardwareSpec
    fast_link = HardwareSpec("x", flops=1e14, hbm_bw=1e12, link_bw=1e13,
                             matmul_dim=128, vmem_bytes=1 << 27)
    slow_link = HardwareSpec("y", flops=1e15, hbm_bw=1e12, link_bw=1e8,
                             matmul_dim=128, vmem_bytes=1 << 27)
    shape, mesh_shape = (1 << 10, 1 << 10, 1 << 10), (16, 16)
    assert comm.plan_comm_pencil(shape, mesh_shape, hw=fast_link) == \
        ("collective", "collective")
    assert comm.plan_comm_pencil(shape, mesh_shape, hw=slow_link) == \
        ("pipelined", "pipelined")
    assert comm.plan_comm_pencil(shape, mesh_shape, hw=slow_link,
                                 overlap_capable=False) == \
        ("collective", "collective")
    # a trivial communicator never pipelines
    assert comm.plan_comm_pencil(shape, (1, 16), hw=slow_link)[0] == \
        "collective"


def test_planner_comm_methods():
    from repro.core.plan import HardwareSpec, Planner
    slow_link = HardwareSpec("y", flops=1e15, hbm_bw=1e12, link_bw=1e8,
                             matmul_dim=128, vmem_bytes=1 << 27)
    pl = Planner(hardware=slow_link, backends=("jnp",))
    assert pl.plan_comm(1 << 14, 1 << 14, 256) == "pipelined"
    assert pl.plan_comm_pencil((1 << 10,) * 3, (16, 16)) == \
        ("pipelined", "pipelined")


def test_exchange_identity_on_one_device():
    """p=1: every backend's exchange must be the identity redistribution."""
    mesh = jax.make_mesh((1,), ("ax",))
    x = np.arange(24, dtype=np.float32).reshape(4, 6)
    pair = (x, -x)
    for spec in comm.COMM_BACKENDS:
        backend = comm.get_backend(spec, chunks=3)

        def local(a, b, _bk=backend):
            return _bk.exchange((a, b), "ax", split=1, concat=0, p=1)

        re, im = shard_map(local, mesh=mesh,
                           in_specs=(P("ax", None), P("ax", None)),
                           out_specs=(P(None, "ax"), P(None, "ax")))(*pair)
        np.testing.assert_allclose(np.asarray(re), x)
        np.testing.assert_allclose(np.asarray(im), -x)


def test_dfft_reexports_stable():
    """plan_comm / COMM_BACKENDS keep their historical dfft home."""
    from repro.core import dfft
    assert dfft.COMM_BACKENDS == ("collective", "pipelined", "agas")
    assert dfft.plan_comm is comm.plan_comm
    assert dfft.padded_half(512, 8) % 8 == 0
