"""Unit tests for the comm-backend subsystem (single device, fast).

Exchange *numerics* across real multi-device meshes live in
tests/_dist_worker.py; here we cover spec resolution, the roofline
planners, and the degenerate p=1 exchange (which also smoke-tests the
jax.shard_map compat shim inside tier-1's fast path).
"""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import comm
from repro.core.compat import shard_map


def test_get_backend_resolution():
    assert isinstance(comm.get_backend("collective"), comm.CollectiveBackend)
    assert isinstance(comm.get_backend("agas"), comm.AgasBackend)
    b = comm.get_backend("pipelined", chunks=6)
    assert isinstance(b, comm.PipelinedBackend) and b.chunks == 6
    # inline chunk override spelling
    assert comm.get_backend("pipelined:8").chunks == 8
    # idempotent on instances
    assert comm.get_backend(b) is b
    with pytest.raises(ValueError):
        comm.get_backend("parcelport")
    with pytest.raises(TypeError):
        comm.get_backend(42)


def test_resolve_axis_backends():
    axes = ("mx", "my")
    # one spec fans out to every axis
    b = comm.resolve_axis_backends("pipelined", axes)
    assert [x.name for x in b] == ["pipelined", "pipelined"]
    # per-axis sequence, ordered as axes
    b = comm.resolve_axis_backends(("collective", "agas"), axes)
    assert [x.name for x in b] == ["collective", "agas"]
    # dict keyed by mesh-axis name; missing axes default to collective
    b = comm.resolve_axis_backends({"my": "pipelined:2"}, axes)
    assert [x.name for x in b] == ["collective", "pipelined"]
    assert b[1].chunks == 2
    with pytest.raises(ValueError):
        comm.resolve_axis_backends(("collective",), axes)
    # a typo'd mesh-axis key must not silently fall back to collective
    with pytest.raises(ValueError):
        comm.resolve_axis_backends({"mz": "agas"}, axes)


def test_plan_comm_pencil_model():
    from repro.core.plan import HardwareSpec
    fast_link = HardwareSpec("x", flops=1e14, hbm_bw=1e12, link_bw=1e13,
                             matmul_dim=128, vmem_bytes=1 << 27)
    slow_link = HardwareSpec("y", flops=1e15, hbm_bw=1e12, link_bw=1e8,
                             matmul_dim=128, vmem_bytes=1 << 27)
    shape, mesh_shape = (1 << 10, 1 << 10, 1 << 10), (16, 16)
    assert comm.plan_comm_pencil(shape, mesh_shape, hw=fast_link) == \
        ("collective", "collective")
    assert comm.plan_comm_pencil(shape, mesh_shape, hw=slow_link) == \
        ("pipelined", "pipelined")
    assert comm.plan_comm_pencil(shape, mesh_shape, hw=slow_link,
                                 overlap_capable=False) == \
        ("collective", "collective")
    # a trivial communicator never pipelines
    assert comm.plan_comm_pencil(shape, (1, 16), hw=slow_link)[0] == \
        "collective"


def test_planner_comm_methods():
    from repro.core.plan import HardwareSpec, Planner
    slow_link = HardwareSpec("y", flops=1e15, hbm_bw=1e12, link_bw=1e8,
                             matmul_dim=128, vmem_bytes=1 << 27)
    pl = Planner(hardware=slow_link, backends=("jnp",))
    assert pl.plan_comm(1 << 14, 1 << 14, 256) == "pipelined"
    assert pl.plan_comm_pencil((1 << 10,) * 3, (16, 16)) == \
        ("pipelined", "pipelined")


def test_exchange_identity_on_one_device():
    """p=1: every backend's exchange must be the identity redistribution."""
    mesh = jax.make_mesh((1,), ("ax",))
    x = np.arange(24, dtype=np.float32).reshape(4, 6)
    pair = (x, -x)
    for spec in comm.COMM_BACKENDS:
        backend = comm.get_backend(spec, chunks=3)

        def local(a, b, _bk=backend):
            return _bk.exchange((a, b), "ax", split=1, concat=0, p=1)

        re, im = shard_map(local, mesh=mesh,
                           in_specs=(P("ax", None), P("ax", None)),
                           out_specs=(P(None, "ax"), P(None, "ax")))(*pair)
        np.testing.assert_allclose(np.asarray(re), x)
        np.testing.assert_allclose(np.asarray(im), -x)


def test_dfft_reexports_stable():
    """plan_comm / COMM_BACKENDS keep their historical dfft home."""
    from repro.core import dfft
    assert dfft.COMM_BACKENDS == ("collective", "pipelined", "agas")
    assert dfft.plan_comm is comm.plan_comm
    assert dfft.padded_half(512, 8) % 8 == 0


# ---------------------------------------------------------------------------
# MEASURE mode (the autotuner itself runs on real meshes in
# tests/_dist_worker.py; here we pin the caching contract and edge cases)
# ---------------------------------------------------------------------------


class _FakeMesh:
    """Just enough mesh for the keyed measure_comm_* wrappers (the raw
    timer is monkeypatched out, so no devices are needed)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


@pytest.fixture
def clean_measure_state():
    comm.forget_measurements()
    before = comm.MEASURE_STATS["timed"]
    yield
    comm.forget_measurements()
    comm.MEASURE_STATS["timed"] = before


def test_get_backend_rejects_unresolved_modes():
    """"auto"/"measure" are entry-point arguments, not backends."""
    for spec in ("auto", "measure"):
        with pytest.raises(ValueError, match="entry point"):
            comm.get_backend(spec)


def test_measure_comm_trivial_communicator():
    """p=1: nothing to measure, collective wins by fiat."""
    mesh = jax.make_mesh((1,), ("ax",))
    best, timings = comm.measure_comm(mesh, "ax", (4, 8), split=1, concat=0)
    assert best == "collective" and timings == {}
    assert comm.measure_comm_slab(64, 64, mesh, "ax") == "collective"


def test_effective_chunks_matches_pipelined_backend():
    """The sweep must time the chunk counts PipelinedBackend will use."""
    assert comm._effective_chunks(4, 32) == 4
    assert comm._effective_chunks(8, 33) == 3     # falls to a divisor
    assert comm._effective_chunks(2, 33) == 1     # no even divisor
    assert comm._effective_chunks(16, 4) == 4     # clamped to width


def test_measure_memo_one_measurement_per_key(monkeypatch,
                                              clean_measure_state):
    """The acceptance contract: the sweep runs once per key — repeat calls
    (e.g. jit retraces) hit the memo, wisdom hits skip it entirely."""
    from repro.core.wisdom import WisdomStore
    calls = []

    def fake_measure(mesh, axis, local_shape, **kw):
        calls.append((axis, tuple(local_shape)))
        return "pipelined:3", {"collective": 2e-3, "pipelined:3": 1e-3,
                               "agas": float("inf")}

    monkeypatch.setattr(comm, "measure_comm", fake_measure)
    mesh = _FakeMesh(fft=8)
    w = WisdomStore()
    assert comm.measure_comm_slab(64, 512, mesh, "fft", wisdom=w) \
        == "pipelined:3"
    assert len(calls) == 1
    # same key again: memo + wisdom hit, no new sweep
    assert comm.measure_comm_slab(64, 512, mesh, "fft", wisdom=w) \
        == "pipelined:3"
    assert len(calls) == 1
    # wisdom carries the verdict to a fresh process (memo cleared)
    rec = w.get("comm/slab/64x512/p8/r2c")
    assert rec["backend"] == "pipelined:3" and rec["seconds"] == 1e-3
    assert rec["candidates"]["agas"] is None      # inf sanitized for JSON
    comm.forget_measurements()
    assert comm.measure_comm_slab(64, 512, mesh, "fft", wisdom=w) \
        == "pipelined:3"
    assert len(calls) == 1
    # no wisdom at all: the process memo still guarantees one sweep per key
    comm.forget_measurements()
    comm.measure_comm_slab(64, 512, mesh, "fft")
    comm.measure_comm_slab(64, 512, mesh, "fft")
    assert len(calls) == 2
    # a different shape is a different key
    comm.measure_comm_slab(64, 1024, mesh, "fft")
    assert len(calls) == 3


def test_measure_pencil_which_mask(monkeypatch, clean_measure_state):
    """Mixed per-axis comm: only the axes that ask get measured."""
    calls = []

    def fake_measure(mesh, axis, local_shape, **kw):
        calls.append(axis)
        return "collective", {"collective": 1e-3}

    monkeypatch.setattr(comm, "measure_comm", fake_measure)
    mesh = _FakeMesh(mx=4, my=2)
    s0, s1 = comm.measure_comm_pencil((16, 32, 64), mesh, ("mx", "my"),
                                      which=(False, True))
    assert s0 is None and s1 == "collective"
    assert calls == ["my"]


def test_measure_pencil_c2r_shares_r2c_key(monkeypatch,
                                           clean_measure_state):
    """The c2r inverse retraces r2c's exchanges with byte-identical probes,
    so it must reuse the forward's verdict instead of re-measuring."""
    calls = []

    def fake_measure(mesh, axis, local_shape, **kw):
        calls.append((axis, tuple(local_shape)))
        return "pipelined:2", {"pipelined:2": 1e-3}

    monkeypatch.setattr(comm, "measure_comm", fake_measure)
    mesh = _FakeMesh(mx=4, my=2)
    fwd = comm.measure_comm_pencil((16, 32, 64), mesh, ("mx", "my"),
                                   kind="r2c")
    assert len(calls) == 2
    inv = comm.measure_comm_pencil((16, 32, 64), mesh, ("mx", "my"),
                                   kind="c2r")
    assert inv == fwd and len(calls) == 2         # zero re-measurement
    # c2c is a genuinely different exchange size (no padded half): new keys
    comm.measure_comm_pencil((16, 32, 64), mesh, ("mx", "my"), kind="c2c")
    assert len(calls) == 4


def test_gather_backends_agree_on_one_device():
    """Chunked vs monolithic gather: identical stacked result."""
    mesh = jax.make_mesh((1,), ("ax",))
    q = np.arange(24, dtype=np.float32).reshape(6, 4)
    s = np.arange(6, dtype=np.float32).reshape(6, 1)
    outs = {}
    for spec in ("collective", "pipelined:3", "agas"):
        backend = comm.get_backend(spec)

        def local(a, b, _bk=backend):
            return _bk.gather((a, b), "ax")

        outs[spec] = shard_map(
            local, mesh=mesh,
            in_specs=(P("ax", None), P("ax", None)),
            out_specs=(P(None, "ax", None), P(None, "ax", None)))(q, s)
    for spec, (qg, sg) in outs.items():
        np.testing.assert_allclose(np.asarray(qg), q[None], err_msg=spec)
        np.testing.assert_allclose(np.asarray(sg), s[None], err_msg=spec)


def test_plan_comm_conv_and_gather_models():
    from repro.core.plan import HardwareSpec
    fast_link = HardwareSpec("x", flops=1e14, hbm_bw=1e12, link_bw=1e13,
                             matmul_dim=128, vmem_bytes=1 << 27)
    slow_link = HardwareSpec("y", flops=1e15, hbm_bw=1e12, link_bw=1e8,
                             matmul_dim=128, vmem_bytes=1 << 27)
    assert comm.plan_comm_conv(8, 64, 256, 256, 8, hw=fast_link) \
        == "collective"
    assert comm.plan_comm_conv(8, 64, 256, 256, 8, hw=slow_link) \
        == "pipelined"
    assert comm.plan_comm_conv(8, 64, 256, 256, 1, hw=slow_link) \
        == "collective"
    # the gather has almost no compute to hide behind (a dequantize-sum),
    # so only an extreme link/compute ratio keeps the monolithic collective
    extreme_link = HardwareSpec("z", flops=1e9, hbm_bw=1e12, link_bw=1e13,
                                matmul_dim=128, vmem_bytes=1 << 27)
    assert comm.plan_comm_gather(1 << 20, 4, hw=extreme_link) == "collective"
    assert comm.plan_comm_gather(1 << 20, 4, hw=slow_link) == "pipelined"
    assert comm.plan_comm_gather(1 << 20, 1, hw=slow_link) == "collective"
