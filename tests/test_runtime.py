"""Fault-tolerance tests: checkpoint atomicity, restart determinism,
preemption, elastic remesh (all on 1 CPU device — mesh=None path)."""

import os
import shutil
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.models.config import ArchConfig, ShapeConfig
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig

ARCH = ArchConfig("tiny", "dense", 2, 64, 4, 2, 128, 256,
                  compute_dtype="float32")
SHAPE = ShapeConfig("smoke", 32, 4, "train")
OPT = AdamWConfig(warmup_steps=2, total_steps=50)


def _trainer(d, every=3):
    return Trainer(ARCH, SHAPE, None, TrainerConfig(ckpt_dir=d,
                                                    ckpt_every=every), OPT)


def test_checkpoint_manager_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep_n=2, async_save=False)
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones((4,), np.int32)}}
    mgr.save(1, tree, extra={"data_step": 1})
    mgr.save(2, tree)
    mgr.save(3, tree)
    assert mgr.all_steps() == [2, 3]        # keep_n gc
    assert mgr.latest_step() == 3
    restored, extra = mgr.restore(3, tree)
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, async_save=False)
    mgr.save(5, {"x": np.zeros(3)})
    files = os.listdir(d)
    assert not any(f.endswith(".tmp") for f in files)
    assert "latest" in files


def test_restart_bit_identical(tmp_path):
    d1 = str(tmp_path / "run_interrupted")
    t = _trainer(d1)
    t.run(3)                                 # ckpt at 3
    t2 = _trainer(d1)
    p2, _, h2 = t2.run(6)                    # resumes 3..5

    d2 = str(tmp_path / "run_clean")
    t3 = _trainer(d2, every=100)
    p3, _, h3 = t3.run(6)

    la = jax.tree_util.tree_leaves(p2)
    lb = jax.tree_util.tree_leaves(p3)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [round(h["loss"], 6) for h in h2] == \
        [round(h["loss"], 6) for h in h3[3:]]


def test_simulated_preemption_and_recovery(tmp_path, monkeypatch):
    d = str(tmp_path / "pre")
    monkeypatch.setenv("REPRO_PREEMPT_AT", "3")
    t = _trainer(d)
    with pytest.raises(SystemExit, match="preemption"):
        t.run(10)
    monkeypatch.delenv("REPRO_PREEMPT_AT")
    assert CheckpointManager(d).latest_step() == 3
    t2 = _trainer(d)
    _, _, h = t2.run(5)
    assert len(h) == 2                       # resumed at 3, ran 3..4


def test_straggler_watchdog_counts(tmp_path):
    t = _trainer(str(tmp_path / "s"))
    t._watchdog(0, 0.1)
    for i in range(1, 5):
        t._watchdog(i, 0.1)
    t._watchdog(5, 10.0)                     # 100x the EWMA
    assert len(t.straggler_events) == 1
    assert t.straggler_events[0][0] == 5


def test_data_iterator_state_resumes(tmp_path):
    """data batches after restart continue the stream (step-indexed)."""
    from repro.data import SyntheticDataset
    ds = SyntheticDataset(ARCH, SHAPE, seed=0)
    b4 = ds.batch_at(4)
    ds2 = SyntheticDataset(ARCH, SHAPE, seed=0)
    np.testing.assert_array_equal(b4["tokens"], ds2.batch_at(4)["tokens"])
    assert not np.array_equal(ds.batch_at(4)["tokens"],
                              ds.batch_at(5)["tokens"])
