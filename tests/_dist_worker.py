"""Multi-device worker, launched by test_distributed.py in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the device-count override
is process-local — it must never leak into the main pytest process).

Each check prints "PASS <name>"; any exception fails the subprocess.
"""

import os
import sys

assert "--xla_force_host_platform_device_count=8" in os.environ.get(
    "XLA_FLAGS", ""), "launch me via test_distributed.py"

import warnings                 # noqa: E402

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import api      # noqa: E402
from repro.core import comm as comm_mod             # noqa: E402
from repro.core import dfft, fftconv, plan          # noqa: E402

# the *_slab/*_pencil checks below exercise the deprecated shims on purpose
warnings.filterwarnings("ignore", category=DeprecationWarning)
from repro.core.compat import shard_map             # noqa: E402
from repro.models import lm                         # noqa: E402
from repro.optim import choose_psum_comm, compressed_psum   # noqa: E402
from repro.parallel import pipeline_forward         # noqa: E402

RNG = np.random.default_rng(0)
PLANNER = plan.Planner(backends=("jnp",))


def check_fft2_slab():
    mesh = jax.make_mesh((8,), ("fft",))
    n, m = 64, 512      # m chosen so the pipelined exchange REALLY chunks
    x = RNG.standard_normal((n, m)).astype(np.float32)
    ref = np.fft.rfft2(x)
    xs = jax.device_put(x, NamedSharding(mesh, P("fft", None)))
    for comm in dfft.COMM_BACKENDS:
        for chunks in (1, 3, 4):
            re, im = dfft.fft2_slab(xs, mesh, "fft", PLANNER, comm=comm,
                                    chunks=chunks)
            z = np.asarray(re)[:, :m // 2 + 1] \
                + 1j * np.asarray(im)[:, :m // 2 + 1]
            err = np.max(np.abs(z - ref)) / np.max(np.abs(ref))
            assert err < 1e-4, (comm, chunks, err)
            if comm != "pipelined":
                break
        # roundtrip per backend (ifft2_slab honors comm too)
        back = dfft.ifft2_slab(dfft.fft2_slab(xs, mesh, "fft", PLANNER,
                                              comm=comm),
                               mesh, "fft", m, PLANNER, comm=comm)
        assert np.max(np.abs(np.asarray(back) - x)) < 1e-4, comm
    # permuted-order columns (digit-transpose elision) roundtrip
    x2 = RNG.standard_normal((256, 256)).astype(np.float32)
    xs2 = jax.device_put(x2, NamedSharding(mesh, P("fft", None)))
    c2 = dfft.fft2_slab(xs2, mesh, "fft", PLANNER, permuted_cols=True)
    back2 = dfft.ifft2_slab(c2, mesh, "fft", 256, PLANNER, permuted_cols=True)
    assert np.max(np.abs(np.asarray(back2) - x2)) < 1e-4
    # transposed-spectrum path (the §Perf-A winning config)
    ct = dfft.fft2_slab(xs, mesh, "fft", PLANNER, keep_transposed=True)
    backt = dfft.ifft2_slab(ct, mesh, "fft", m, PLANNER, from_transposed=True)
    assert np.max(np.abs(np.asarray(backt) - x)) < 1e-4
    print("PASS fft2_slab")


def check_fft3_pencil():
    mesh = jax.make_mesh((4, 2), ("mx", "my"))
    x = (RNG.standard_normal((16, 32, 64)).astype(np.float32)
         + 1j * RNG.standard_normal((16, 32, 64)).astype(np.float32))
    pair = (jax.device_put(np.real(x).astype(np.float32),
                           NamedSharding(mesh, P("mx", "my", None))),
            jax.device_put(np.imag(x).astype(np.float32),
                           NamedSharding(mesh, P("mx", "my", None))))
    ref = np.fft.fftn(x)
    refmax = np.max(np.abs(ref))
    # every comm backend: forward == numpy oracle AND full inverse roundtrip
    for comm in dfft.COMM_BACKENDS:
        rr, ri = dfft.fft3_pencil(pair, mesh, ("mx", "my"), PLANNER,
                                  comm=comm)
        err = np.max(np.abs((np.asarray(rr) + 1j * np.asarray(ri)) - ref)) \
            / refmax
        assert err < 1e-4, (comm, err)
        br, bi = dfft.ifft3_pencil((rr, ri), mesh, ("mx", "my"), PLANNER,
                                   comm=comm)
        back = np.asarray(br) + 1j * np.asarray(bi)
        assert np.max(np.abs(back - x)) < 1e-4, comm
    # per-axis backend selection: row/column communicators differ (incl.
    # measured/planned entries mixed with explicit specs)
    for comm in (("pipelined", "collective"), {"my": "agas"}, "auto",
                 "measure", ("measure", "collective"), {"mx": "measure"}):
        rr, ri = dfft.fft3_pencil(pair, mesh, ("mx", "my"), PLANNER,
                                  comm=comm)
        err = np.max(np.abs((np.asarray(rr) + 1j * np.asarray(ri)) - ref)) \
            / refmax
        assert err < 1e-4, (comm, err)
    print("PASS fft3_pencil")


def check_rfft3_pencil():
    mesh = jax.make_mesh((4, 2), ("mx", "my"))
    nx, ny, nz = 16, 32, 64
    x = RNG.standard_normal((nx, ny, nz)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("mx", "my", None)))
    ref = np.fft.rfftn(x)
    refmax = np.max(np.abs(ref))
    for comm in dfft.COMM_BACKENDS:
        re, im = dfft.rfft3_pencil(xs, mesh, ("mx", "my"), PLANNER,
                                   comm=comm)
        z = (np.asarray(re)[..., :nz // 2 + 1]
             + 1j * np.asarray(im)[..., :nz // 2 + 1])
        err = np.max(np.abs(z - ref)) / refmax
        assert err < 1e-4, (comm, err)
        # c2r roundtrip through the padded half spectrum
        back = dfft.irfft3_pencil((re, im), mesh, ("mx", "my"), nz, PLANNER,
                                  comm=comm)
        assert np.max(np.abs(np.asarray(back) - x)) < 1e-4, comm
    print("PASS rfft3_pencil")


def check_fftconv_seq_sharded():
    mesh = jax.make_mesh((8,), ("sp",))
    b, l, d = 2, 512, 4
    u = RNG.standard_normal((b, l, d)).astype(np.float32)
    k = (RNG.standard_normal((d, l))
         * np.exp(-np.arange(l) / 32)[None]).astype(np.float32)
    nf = 2 * l
    ref = np.fft.irfft(
        np.fft.rfft(np.pad(u, ((0, 0), (0, nf - l), (0, 0))), axis=1)
        * np.fft.rfft(np.pad(k.T[None], ((0, 0), (0, nf - l), (0, 0))), axis=1),
        axis=1, n=nf)[:, :l, :]
    us = jax.device_put(u, NamedSharding(mesh, P(None, "sp", None)))
    for comm in dfft.COMM_BACKENDS + ("auto", "measure"):
        y = fftconv.fft_conv_seq_sharded(us, jnp.asarray(k), mesh, "sp",
                                         PLANNER, comm=comm)
        err = np.max(np.abs(np.asarray(y) - ref)) / np.max(np.abs(ref))
        assert err < 1e-4, (comm, err)
    print("PASS fftconv_seq_sharded")


def check_compressed_psum():
    mesh = jax.make_mesh((8,), ("pod",))
    xs = RNG.standard_normal((8, 1000)).astype(np.float32)
    ref = xs.sum(axis=0)

    # every gather backend, plus the measured choice resolved outside
    # shard_map via choose_psum_comm (wisdom-cached like the FFT paths)
    measured = choose_psum_comm(mesh, "pod", (1000,), mode="measure",
                                wisdom=PLANNER.wisdom)
    assert PLANNER.wisdom.get("comm/gather/1000/b256/p8") is not None
    for comm in ("collective", "pipelined:2", "agas", measured,
                 choose_psum_comm(mesh, "pod", (1000,), mode="auto")):

        def body(x, _c=comm):
            out, err = compressed_psum(x[0], "pod", comm=_c)
            return out[None], err[None]

        out, err = jax.jit(shard_map(
            body, mesh=mesh, in_specs=P("pod", None),
            out_specs=(P("pod", None), P("pod", None))))(xs)
        got = np.asarray(out)[0]
        rel = np.abs(got - ref) / (np.abs(ref) + 1e-3)
        assert np.median(rel) < 0.02, (comm, np.median(rel))
        # error feedback residual is bounded by the quantization step
        assert np.max(np.abs(np.asarray(err))) < 0.05, comm
    print("PASS compressed_psum")


def check_measure_comm():
    """The comm="measure" acceptance contract on a REAL 8-device mesh:
    on-mesh timing picks a backend, the verdict lands in the unified
    wisdom store, and repeat calls perform ZERO measurements — including
    across planner instances through a wisdom file."""
    import tempfile

    mesh = jax.make_mesh((8,), ("fft",))
    n, m = 64, 512
    x = RNG.standard_normal((n, m)).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("fft", None)))
    ref = np.fft.rfft2(x)

    wpath = tempfile.mktemp(suffix=".json")
    planner = plan.Planner(backends=("jnp",), wisdom_path=wpath)
    before = comm_mod.MEASURE_STATS["timed"]
    re, im = dfft.fft2_slab(xs, mesh, "fft", planner, comm="measure")
    timed = comm_mod.MEASURE_STATS["timed"] - before
    assert timed >= 3, timed          # collective + agas + >=1 chunk count
    z = np.asarray(re)[:, :m // 2 + 1] + 1j * np.asarray(im)[:, :m // 2 + 1]
    assert np.max(np.abs(z - ref)) / np.max(np.abs(ref)) < 1e-4

    # the verdict is a concrete, resolvable backend in comm/* wisdom
    rec = planner.wisdom.get(f"comm/slab/{n}x{m}/p8/r2c")
    assert rec is not None and rec["backend"] is not None
    comm_mod.get_backend(rec["backend"])
    assert rec["candidates"]["collective"] is not None

    # second call + inverse: zero new measurements (memo + wisdom hits)
    snap = comm_mod.MEASURE_STATS["timed"]
    back = dfft.ifft2_slab(
        dfft.fft2_slab(xs, mesh, "fft", planner, comm="measure"),
        mesh, "fft", m, planner, comm="measure")
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-4
    assert comm_mod.MEASURE_STATS["timed"] == snap

    # a fresh planner reading the wisdom file needs no measurements either,
    # even after the in-process memo is dropped (FFTW wisdom semantics)
    comm_mod.forget_measurements()
    planner2 = plan.Planner(backends=("jnp",), wisdom_path=wpath)
    re2, im2 = dfft.fft2_slab(xs, mesh, "fft", planner2, comm="measure")
    assert comm_mod.MEASURE_STATS["timed"] == snap
    z2 = np.asarray(re2)[:, :m // 2 + 1] + 1j * np.asarray(im2)[:, :m // 2 + 1]
    assert np.max(np.abs(z2 - ref)) / np.max(np.abs(ref)) < 1e-4

    # pencil: per-communicator measurement, then a zero-measurement retrace
    mesh2 = jax.make_mesh((4, 2), ("mx", "my"))
    xc = RNG.standard_normal((16, 32, 64)).astype(np.float32)
    pair = (jax.device_put(xc, NamedSharding(mesh2, P("mx", "my", None))),
            jax.device_put(np.zeros_like(xc),
                           NamedSharding(mesh2, P("mx", "my", None))))
    rr, ri = dfft.fft3_pencil(pair, mesh2, ("mx", "my"), planner2,
                              comm="measure")
    for ax in ("ax0", "ax1"):
        assert planner2.wisdom.get(
            f"comm/pencil/16x32x64/mesh4x2/c2c/{ax}") is not None
    snap2 = comm_mod.MEASURE_STATS["timed"]
    br, bi = dfft.ifft3_pencil((rr, ri), mesh2, ("mx", "my"), planner2,
                               comm="measure")
    assert comm_mod.MEASURE_STATS["timed"] == snap2
    back3 = np.asarray(br) + 1j * np.asarray(bi)
    assert np.max(np.abs(back3 - xc)) < 1e-4

    # r2c/c2r pencil: the inverse shares the forward's verdict (byte-
    # identical exchanges), so the roundtrip measures only on the forward
    re3, im3 = dfft.rfft3_pencil(pair[0], mesh2, ("mx", "my"), planner2,
                                 comm="measure")
    snap3 = comm_mod.MEASURE_STATS["timed"]
    back_r = dfft.irfft3_pencil((re3, im3), mesh2, ("mx", "my"), 64,
                                planner2, comm="measure")
    assert comm_mod.MEASURE_STATS["timed"] == snap3
    assert np.max(np.abs(np.asarray(back_r) - xc)) < 1e-4

    # wisdom export -> import round-trips the comm verdicts byte-identically
    text = planner2.export_wisdom()
    p3 = plan.Planner(backends=("jnp",))
    p3.import_wisdom(text)
    assert p3.export_wisdom() == text
    os.unlink(wpath)
    print("PASS measure_comm")


def check_plan_nd():
    """The plan_nd acceptance contract on a REAL 8-device mesh: the
    roofline picks local for small shapes and slab/pencil for large ones,
    dfft/* verdicts persist to the unified wisdom file, mode="measured"
    times the finalists exactly once, and fftn/rfftn match numpy on
    non-divisible shapes and batched pencil inputs."""
    import tempfile

    mesh = jax.make_mesh((8,), ("fft",))
    mesh2 = jax.make_mesh((4, 2), ("mx", "my"))
    wpath = tempfile.mktemp(suffix=".json")
    planner = plan.Planner(backends=("jnp",), wisdom_path=wpath)

    # roofline decomposition choice (ESTIMATE mode)
    assert api.plan_nd((64, 64), "r2c", mesh=mesh,
                       planner=planner).decomp == "local"
    large = api.plan_nd((1024, 1024), "r2c", mesh=mesh, planner=planner)
    assert large.decomp == "slab", large
    big3 = api.plan_nd((128, 128, 128), "c2c", mesh=mesh2, planner=planner)
    assert big3.decomp == "pencil", big3
    assert set(big3.mesh_axes) == {"mx", "my"}

    # verdicts persisted under dfft/* in the unified wisdom file; a fresh
    # planner reading the file reconstructs identical plans
    keys = list(planner.wisdom.keys("dfft/"))
    assert len(keys) == 3, keys
    planner2 = plan.Planner(backends=("jnp",), wisdom_path=wpath)
    assert api.plan_nd((1024, 1024), "r2c", mesh=mesh,
                       planner=planner2) == large

    # measured mode: every finalist timed once (with its exchanges resolved
    # through measure_comm_*), wisdom hit re-times nothing.  The shape is
    # deliberately NOT one check_measure_comm later measures fresh — the
    # comm verdict memo is process-global.
    probes = api.PLAN_ND_STATS["timed"]
    ndm = api.plan_nd((64, 320), "r2c", mesh=mesh, planner=planner,
                      mode="measured")
    timed = api.PLAN_ND_STATS["timed"] - probes
    assert timed >= 2, timed            # local + slab at least
    assert ndm.measured_cost > 0
    assert planner.wisdom.get("comm/slab/64x320/p8/r2c") is not None
    snap = api.PLAN_ND_STATS["timed"]
    ndm2 = api.plan_nd((64, 320), "r2c", mesh=mesh, planner=planner,
                       mode="measured")
    assert api.PLAN_ND_STATS["timed"] == snap and ndm2 == ndm

    # regression (non-divisible Mh on a 3-device mesh): m=12 -> mh=7 which
    # does not divide p=3, and n=10 does not either; collect() crops via
    # the NdPlan instead of assuming the padded column count
    mesh3 = jax.make_mesh((3,), ("s",))
    x = RNG.standard_normal((10, 12)).astype(np.float32)
    nd3 = api.plan_nd((10, 12), "r2c", mesh=mesh3, planner=planner,
                      decomp="slab", axes=("s",))
    assert nd3.padded_spectrum_shape == (12, 9)
    padded = api.execute_nd(nd3, x, mesh=mesh3, planner=planner)
    re, im = dfft.collect(padded, nd3)
    ref = np.fft.rfftn(x)
    assert re.shape == ref.shape == (10, 7)
    assert np.max(np.abs((re + 1j * im) - ref)) / np.max(np.abs(ref)) < 1e-4
    back = api.irfftn(api.plan_nd((10, 12), "r2c", mesh=mesh3,
                                  planner=planner, decomp="slab",
                                  axes=("s",)).crop_pair(padded),
                      shape=(10, 12), mesh=mesh3, plan=nd3, planner=planner)
    assert np.max(np.abs(np.asarray(back) - x)) < 1e-4

    # fftn/rfftn vs numpy across decompositions and device counts,
    # including odd/prime axes and leading batch dims (the multi-device
    # complement of the hypothesis property in tests/test_properties.py)
    mesh4 = jax.make_mesh((4,), ("fft4",))
    mesh22 = jax.make_mesh((2, 2), ("qx", "qy"))
    cases = [
        ((16, 24), (), "slab", mesh, ("fft",)),
        ((10, 7), (2,), "slab", mesh4, ("fft4",)),          # odd/prime
        ((8, 12, 16), (), "pencil", mesh2, ("mx", "my")),
        ((6, 10, 9), (2,), "pencil", mesh2, ("mx", "my")),  # batched+mixed
        ((7, 6, 13), (3,), "pencil", mesh22, ("qx", "qy")),
        ((12, 8, 16), (2,), "slab", mesh, ("fft",)),        # batched 3D slab
    ]
    for shape, batch, decomp, m, axes in cases:
        xr = RNG.standard_normal(batch + shape).astype(np.float32)
        tf_axes = tuple(range(-len(shape), 0))
        ndr = api.plan_nd(shape, "r2c", mesh=m, planner=planner,
                          decomp=decomp, axes=axes)
        rr, ri = api.rfftn(xr, mesh=m, plan=ndr, planner=planner,
                           ndim=len(shape))
        refr = np.fft.rfftn(xr, axes=tf_axes)
        got = np.asarray(rr) + 1j * np.asarray(ri)
        assert got.shape == refr.shape, (shape, batch, decomp)
        err = np.max(np.abs(got - refr)) / np.max(np.abs(refr))
        assert err < 1e-4, (shape, batch, decomp, err)
        backr = api.irfftn((rr, ri), shape=shape, mesh=m, plan=ndr,
                           planner=planner)
        assert np.max(np.abs(np.asarray(backr) - xr)) < 1e-3, (shape, decomp)

        ndc = api.plan_nd(shape, "c2c", mesh=m, planner=planner,
                          decomp=decomp, axes=axes)
        cr, ci = api.fftn(xr, mesh=m, plan=ndc, planner=planner,
                          ndim=len(shape))
        refc = np.fft.fftn(xr, axes=tf_axes)
        gotc = np.asarray(cr) + 1j * np.asarray(ci)
        errc = np.max(np.abs(gotc - refc)) / np.max(np.abs(refc))
        assert errc < 1e-4, (shape, batch, decomp, errc)

    os.unlink(wpath)
    print("PASS plan_nd")


def check_plan_nd_generalized():
    """PR-4 acceptance on REAL 8-device meshes: multi-axis pencil beyond
    3D (k=2 on a 4-D shape over a 2-axis mesh, k=3 over a 3-axis mesh,
    mixed radix and batched), the factor-split distributed-1D candidate
    selected and executed numpy-exactly, and the planned transposed layout
    saving one exchange each way."""
    mesh42 = jax.make_mesh((4, 2), ("mx", "my"))
    mesh222 = jax.make_mesh((2, 2, 2), ("ma", "mb", "mc"))
    mesh8 = jax.make_mesh((8,), ("fft",))
    planner = plan.Planner(backends=("jnp",))

    # a 4-D c2c shape over a 2-axis mesh enumerates multi-axis pencil
    # candidates (and over a 3-axis mesh, the full k=3 chain)
    cands = api._candidates((8, 6, 5, 8), "c2c", {"mx": 4, "my": 2})
    assert ("pencil", ("mx", "my")) in cands, cands
    cands3 = api._candidates((8, 6, 5, 8), "c2c",
                             {"ma": 2, "mb": 2, "mc": 2})
    assert ("pencil", ("ma", "mb", "mc")) in cands3, cands3

    # k=2 and k=3 pencil chains execute numpy-exactly: mixed radix
    # (nothing divides every communicator) AND a leading batch dim
    shape = (8, 6, 5, 8)
    x = (RNG.standard_normal((2,) + shape)
         + 1j * RNG.standard_normal((2,) + shape)).astype(np.complex64)
    ref = np.fft.fftn(x, axes=(-4, -3, -2, -1))
    refmax = np.max(np.abs(ref))
    for mesh, axes in ((mesh42, ("mx", "my")),
                       (mesh222, ("ma", "mb", "mc"))):
        nd = api.plan_nd(shape, "c2c", mesh=mesh, planner=planner,
                         decomp="pencil", axes=axes)
        re, im = api.fftn(x, mesh=mesh, plan=nd, planner=planner, ndim=4)
        got = np.asarray(re) + 1j * np.asarray(im)
        assert got.shape == ref.shape, axes
        assert np.max(np.abs(got - ref)) / refmax < 1e-4, axes
        br, bi = api.ifftn((re, im), mesh=mesh, plan=nd, planner=planner,
                           ndim=4)
        back = np.asarray(br) + 1j * np.asarray(bi)
        assert np.max(np.abs(back - x)) < 1e-3, axes
    # r2c through the k=3 chain (padded half spectrum, odd middle axes)
    xr = RNG.standard_normal((6, 10, 5, 12)).astype(np.float32)
    ndr = api.plan_nd((6, 10, 5, 12), "r2c", mesh=mesh222, planner=planner,
                      decomp="pencil", axes=("ma", "mb", "mc"))
    rr, ri = api.rfftn(xr, mesh=mesh222, plan=ndr, planner=planner)
    refr = np.fft.rfftn(xr)
    gotr = np.asarray(rr) + 1j * np.asarray(ri)
    assert gotr.shape == refr.shape
    assert np.max(np.abs(gotr - refr)) / np.max(np.abs(refr)) < 1e-4
    backr = api.irfftn((rr, ri), shape=(6, 10, 5, 12), mesh=mesh222,
                       plan=ndr, planner=planner)
    assert np.max(np.abs(np.asarray(backr) - xr)) < 1e-3

    # distributed 1D: the roofline picks the factor split over gather-local
    # for a large transform, and the executor matches numpy.fft.fft
    n = 1 << 20
    nd1 = api.plan_nd((n,), "c2c", mesh=mesh8, planner=planner)
    assert nd1.decomp == "factor1d", nd1
    assert nd1.factors[0] * nd1.factors[1] == n
    assert nd1.factors[0] % 8 == 0 and nd1.factors[1] % 8 == 0
    xc = (RNG.standard_normal((n,))
          + 1j * RNG.standard_normal((n,))).astype(np.complex64)
    xs = (jax.device_put(np.real(xc), NamedSharding(mesh8, P("fft"))),
          jax.device_put(np.imag(xc), NamedSharding(mesh8, P("fft"))))
    re1, im1 = api.fftn(xs, mesh=mesh8, plan=nd1, planner=planner)
    ref1 = np.fft.fft(xc)
    got1 = np.asarray(re1) + 1j * np.asarray(im1)
    err1 = np.max(np.abs(got1 - ref1)) / np.max(np.abs(ref1))
    assert err1 < 1e-3, err1            # 1M-point f32 accumulations
    b1r, b1i = api.ifftn((re1, im1), mesh=mesh8, plan=nd1, planner=planner)
    back1 = np.asarray(b1r) + 1j * np.asarray(b1i)
    assert np.max(np.abs(back1 - xc)) < 1e-3
    # small 1D still stays local (three latencies beat one gather)
    assert api.plan_nd((4096,), "c2c", mesh=mesh8,
                       planner=planner).decomp == "local"

    # comm="measure" through the NEW paths: the k=3 pencil chain (one
    # on-mesh-timed verdict per plane communicator, probe shapes from the
    # executor's own padded chain) and the factor1d stage-A exchange
    ndm = api.plan_nd(shape, "c2c", mesh=mesh222, planner=planner,
                      decomp="pencil", axes=("ma", "mb", "mc"),
                      comm="measure")
    assert len(ndm.comm) == 3
    assert all(s not in ("auto", "measure") for s in ndm.comm), ndm.comm
    shape_tag = "x".join(str(s) for s in shape)
    for j in range(3):
        assert planner.wisdom.get(
            f"comm/pencil/{shape_tag}/mesh2x2x2/c2c/ax{j}") is not None
    rem, imm = api.fftn(x, mesh=mesh222, plan=ndm, planner=planner, ndim=4)
    gotm = np.asarray(rem) + 1j * np.asarray(imm)
    assert np.max(np.abs(gotm - ref)) / refmax < 1e-4
    nm = 1 << 16
    nd1m = api.plan_nd((nm,), "c2c", mesh=mesh8, planner=planner,
                       decomp="factor1d", axes=("fft",), comm="measure")
    (spec1m,) = nd1m.comm
    assert spec1m not in ("auto", "measure"), spec1m
    f1, f2 = nd1m.factors
    assert planner.wisdom.get(
        f"comm/factor1d/{nm}/{f1}x{f2}/p8") is not None
    xm = (RNG.standard_normal((nm,))
          + 1j * RNG.standard_normal((nm,))).astype(np.complex64)
    rem1, imm1 = api.fftn(xm, mesh=mesh8, plan=nd1m, planner=planner)
    refm1 = np.fft.fft(xm)
    errm1 = np.max(np.abs((np.asarray(rem1) + 1j * np.asarray(imm1))
                          - refm1)) / np.max(np.abs(refm1))
    assert errm1 < 1e-3, errm1

    # planned transposed layout: one exchange forward, one backward
    # (counted through a spy backend), numpy-exact values either way
    class Spy(comm_mod.CollectiveBackend):
        count = 0

        def exchange(self, c, axis_name, **kw):
            Spy.count += 1
            return super().exchange(c, axis_name, **kw)

    xt = RNG.standard_normal((64, 512)).astype(np.float32)
    xts = jax.device_put(xt, NamedSharding(mesh8, P("fft", None)))
    for layout, n_fwd in (("natural", 2), ("transposed", 1)):
        ndt = api.plan_nd((64, 512), "r2c", mesh=mesh8, planner=planner,
                          decomp="slab", axes=("fft",), comm=Spy(),
                          output_layout=layout)
        Spy.count = 0
        ct = api.execute_nd(ndt, xts, mesh=mesh8, planner=planner)
        assert Spy.count == n_fwd, (layout, Spy.count)
        z = (np.asarray(ct[0]) + 1j * np.asarray(ct[1]))[:, :512 // 2 + 1]
        reft = np.fft.rfft2(xt)
        assert np.max(np.abs(z - reft)) / np.max(np.abs(reft)) < 1e-4
        Spy.count = 0
        backt = api.execute_nd_inverse(ndt, ct, mesh=mesh8, planner=planner)
        assert Spy.count == n_fwd, (layout, Spy.count)
        assert np.max(np.abs(np.asarray(backt)[:64] - xt)) < 1e-4
    print("PASS plan_nd_generalized")


def check_pipeline_forward():
    mesh = jax.make_mesh((4,), ("pod",))
    m_mb, mb, d = 8, 4, 16
    x = RNG.standard_normal((m_mb, mb, d)).astype(np.float32)
    w = RNG.standard_normal((4, d, d)).astype(np.float32) * 0.3

    def stage(wl, xin):                    # each stage: x @ w_stage
        return jnp.tanh(xin @ wl[0])

    def run(w_all, xin):
        return pipeline_forward(stage, w_all, xin, "pod")

    y = jax.jit(shard_map(
        run, mesh=mesh, in_specs=(P("pod", None, None), P(None, None, None)),
        out_specs=P(None, None, None), check_vma=False))(w, x)
    # reference: sequential stages
    ref = x
    for s in range(4):
        ref = np.tanh(ref @ w[s])
    err = np.max(np.abs(np.asarray(y) - ref))
    assert err < 1e-5, err

    # differentiability (GPipe backward through ppermute)
    def loss(w_all):
        return jnp.sum(shard_map(
            run, mesh=mesh, in_specs=(P("pod", None, None),
                                      P(None, None, None)),
            out_specs=P(None, None, None), check_vma=False)(w_all, x) ** 2)

    g = jax.jit(jax.grad(loss))(w)
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.abs(np.asarray(g)).sum() > 0
    print("PASS pipeline_forward")


def check_sharded_train_equivalence():
    """4-device FSDP+TP train step == single-device step (GSPMD correctness)."""
    from repro.configs import get_smoke_config
    from repro.models.params import sharding_rules
    from repro.parallel import make_rules, logical_shardings

    cfg = get_smoke_config("granite_8b")
    params = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    loss1 = float(lm.loss_fn(params, cfg, batch)[0])

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rules = make_rules(mesh)
    pspecs = logical_shardings(mesh, lm.model_meta(cfg), rules)
    params_sh = jax.tree_util.tree_map(jax.device_put, params, pspecs)

    def sharded_loss(p, b):
        with sharding_rules(mesh, rules):
            return lm.loss_fn(p, cfg, b, num_groups=2)[0]

    loss2 = float(jax.jit(sharded_loss)(params_sh, batch))
    assert abs(loss1 - loss2) < 5e-3, (loss1, loss2)
    print("PASS sharded_train_equivalence")


def check_dryrun_cell_tiny():
    """build_cell compiles on a small mesh (structure check for specs.py)."""
    from repro.launch.specs import cache_pspecs
    from repro.parallel import make_rules, sanitized_shardings
    from repro.configs import get_smoke_config

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = make_rules(mesh)
    for arch in ("granite_8b", "zamba2_7b", "xlstm_1_3b", "phi35_moe_42b"):
        cfg = get_smoke_config(arch)
        cache_abs = jax.eval_shape(lambda c=cfg: lm.init_cache(c, 8, 64))
        specs = cache_pspecs(cfg, 8, mesh, rules)
        sh = sanitized_shardings(mesh, cache_abs, specs)   # structure match
        assert jax.tree_util.tree_structure(sh) == \
            jax.tree_util.tree_structure(cache_abs)
    print("PASS dryrun_cell_tiny")


def check_pipelined_lm_equivalence():
    """Pod-axis GPipe loss == plain loss (same params, same batch)."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.parallel import make_rules
    from repro.parallel.pipelined_lm import (pipelined_loss_fn,
                                             pipeline_param_shardings)

    cfg = dataclasses.replace(get_smoke_config("granite_8b"), num_layers=4)
    params = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    ref = float(lm.loss_fn(params, cfg, batch)[0])

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = make_rules(mesh, pipeline_pods=True)
    pspecs = pipeline_param_shardings(mesh, lm.model_meta(cfg), rules)
    params_sh = jax.tree_util.tree_map(jax.device_put, params, pspecs)

    loss = float(jax.jit(
        lambda p, b: pipelined_loss_fn(p, cfg, b, mesh, rules,
                                       num_microbatches=4)[0]
    )(params_sh, batch))
    assert abs(loss - ref) < 5e-3, (loss, ref)

    # gradients flow through the pipeline (ppermute transpose)
    g = jax.jit(jax.grad(
        lambda p: pipelined_loss_fn(p, cfg, batch, mesh, rules,
                                    num_microbatches=4)[0]))(params_sh)
    gn = sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32))))
             for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("PASS pipelined_lm_equivalence")


def check_serve_profile_equivalence():
    """Weight-stationary serve layout (bf16 reduce, expert-resident weights)
    computes the same loss as the training layout."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models.params import sharding_rules
    from repro.parallel import make_rules, logical_shardings

    cfg = get_smoke_config("phi35_moe_42b")
    params = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    ref = float(lm.loss_fn(params, cfg, batch)[0])

    cfg_s = dataclasses.replace(cfg, reduce_dtype="bfloat16")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = make_rules(mesh, profile="serve")
    pspecs = logical_shardings(mesh, lm.model_meta(cfg_s), rules)
    params_sh = jax.tree_util.tree_map(jax.device_put, params, pspecs)

    def f(p, b):
        with sharding_rules(mesh, rules):
            return lm.loss_fn(p, cfg_s, b, num_groups=4)[0]

    got = float(jax.jit(f)(params_sh, batch))
    assert abs(got - ref) < 2e-2, (got, ref)   # bf16 reductions: loose tol
    print("PASS serve_profile_equivalence")


if __name__ == "__main__":
    check_fft2_slab()
    check_fft3_pencil()
    check_rfft3_pencil()
    check_fftconv_seq_sharded()
    check_plan_nd()
    check_plan_nd_generalized()
    check_measure_comm()
    check_compressed_psum()
    check_pipeline_forward()
    check_sharded_train_equivalence()
    check_dryrun_cell_tiny()
    check_pipelined_lm_equivalence()
    check_serve_profile_equivalence()
    print("ALL_DIST_OK")
