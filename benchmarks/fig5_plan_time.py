"""Paper Fig. 5: planning time — estimated plans are ~free, measured plans
cost orders of magnitude more (FFTW's >50x planning-time gap)."""

from __future__ import annotations

import numpy as np

from repro.core import plan

from .common import emit


def run(sizes=(256, 1024, 4096)) -> None:
    for n in sizes:
        for mode in ("estimate", "measured"):
            planner = plan.Planner(mode=mode,
                                   backends=("jnp", "jnp_karatsuba",
                                             "xla_native"),
                                   hardware=plan.CPU_LOCAL)
            planner.plan(n, "c2c", batch=32)
            emit(f"fig5/{mode}/n{n}", planner.last_plan_seconds)
        # wisdom hit cost
        planner.plan(n, "c2c", batch=32)
        emit(f"fig5/wisdom_hit/n{n}", planner.last_plan_seconds)


if __name__ == "__main__":
    run()
