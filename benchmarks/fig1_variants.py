"""Paper Fig. 1: strong-scaling runtimes of the implementation variants.

On this container "scaling" is over problem size rather than cores (1 CPU
core); the *ordering* of the variants is the paper's claim under test:
bulk-synchronous (for_loop) <= sync <= opt < naive, with agas slowest.
Also reproduces the paper's task-size study (task granularity vs overhead).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import plan, variants

from .common import emit, time_fn


def run(sizes=(256, 512, 1024), task_size: int = 8) -> None:
    planner = plan.Planner(mode="estimate", backends=("jnp",))
    rng = np.random.default_rng(0)
    for n in sizes:
        x = jax.numpy.asarray(rng.standard_normal((n, n)), jax.numpy.float32)
        base = None
        for name in ("for_loop", "future_sync", "future_opt", "future_naive",
                     "future_agas", "strided"):
            fn = jax.jit(lambda a, _n=name: variants.run_variant(
                _n, a, planner, task_size=task_size))
            t = time_fn(fn, x)
            if name == "for_loop":
                base = t
            emit(f"fig1/{name}/n{n}", t, f"rel_to_for_loop={t / base:.2f}")

    # task-size sweep (the paper's 'adjustable task size' insight)
    n = 512
    x = jax.numpy.asarray(rng.standard_normal((n, n)), jax.numpy.float32)
    for ts in (1, 2, 4, 8, 16, 64, 256):
        fn = jax.jit(lambda a, _t=ts: variants.run_variant(
            "future_naive", a, planner, task_size=_t))
        t = time_fn(fn, x)
        emit(f"fig1/task_size/{ts}", t, f"rows_per_task={ts}")


if __name__ == "__main__":
    run()
