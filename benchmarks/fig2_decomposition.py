"""Paper Fig. 2: runtime decomposition per algorithmic step
(fft1 / transpose / fft2 / transpose-back) for the synchronized variants —
plus the *decomposition planner's* verdicts: for each problem size, what
`repro.core.api.plan_nd` scores for local vs slab vs pencil on reference
meshes, and which it picks on "auto".  The scores come from the roofline
model (abstract meshes — no devices needed), so the column shows the
planner's reasoning next to the measured per-stage numbers."""

from __future__ import annotations

import jax
import numpy as np

from repro.core import api, plan, variants

from .common import emit, time_fn


def run(n: int = 512) -> None:
    planner = plan.Planner(mode="estimate", backends=("jnp",))
    rng = np.random.default_rng(0)
    x = jax.numpy.asarray(rng.standard_normal((n, n)), jax.numpy.float32)

    stages = variants.staged_for_loop(x, planner)
    val = x
    total = 0.0
    for name, fn in stages:
        t = time_fn(fn, val)
        val = fn(val)
        total += t
        emit(f"fig2/staged/{name}/n{n}", t)
    emit(f"fig2/staged/total/n{n}", total)

    fused = jax.jit(lambda a: variants.run_variant("for_loop", a, planner))
    t_fused = time_fn(fused, x)
    emit(f"fig2/fused_for_loop/n{n}", t_fused,
         f"stage_sum_over_fused={total / t_fused:.2f}")

    # ------------------------------------------------------------------
    # decomposition planner column: local vs slab vs pencil vs factor1d
    # vs auto, per shape, on reference 8-way / 4x2 / 2x2x2 meshes
    # (roofline scores; pencil beyond 3D and distributed 1D included)
    # ------------------------------------------------------------------
    def supported(decomp, shape, kind, mesh):
        # derive feasibility from the planner's own candidate space so the
        # benchmark column can never disagree with what plan_nd enumerates
        return decomp == "local" or any(
            dec == decomp for dec, _ in api._candidates(shape, kind, mesh))

    for shape, kind, mesh in (
            ((64, 64), "r2c", {"fft": 8}),
            ((n, n), "r2c", {"fft": 8}),
            ((4 * n, 4 * n), "r2c", {"fft": 8}),
            ((64, 64, 64), "c2c", {"mx": 4, "my": 2}),
            ((128, 128, 128), "c2c", {"mx": 4, "my": 2}),
            ((64, 64, 32, 32), "c2c", {"mx": 4, "my": 2}),     # 4D, k=2
            ((32, 32, 32, 64), "c2c", {"ma": 2, "mb": 2, "mc": 2}),  # k=3
            ((1 << 20,), "c2c", {"fft": 8})):                  # dist 1D
        tag = "x".join(str(s) for s in shape)
        scores = {}
        for decomp in api.DECOMPS:
            if not supported(decomp, shape, kind, mesh):
                continue
            nd = planner.plan_nd(shape, kind, mesh=mesh, decomp=decomp)
            scores[decomp] = nd.est_cost
            emit(f"fig2/decomp/{decomp}/{tag}", nd.est_cost,
                 f"mesh_axes={nd.mesh_axes}")
        auto = planner.plan_nd(shape, kind, mesh=mesh)
        emit(f"fig2/decomp/auto/{tag}", auto.est_cost,
             f"picked={auto.decomp};"
             + ";".join(f"{k}={v:.2e}" for k, v in scores.items()))
        # the planned output layout: what the saved restore exchange is
        # worth on this shape (slab decompositions only)
        if len(shape) >= 2:
            tra = planner.plan_nd(shape, kind, mesh=mesh, decomp="slab",
                                  output_layout="transposed")
            emit(f"fig2/decomp/slab_transposed/{tag}", tra.est_cost,
                 f"saved_vs_slab={scores['slab'] - tra.est_cost:.2e}")


if __name__ == "__main__":
    run()
