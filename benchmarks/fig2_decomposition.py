"""Paper Fig. 2: runtime decomposition per algorithmic step
(fft1 / transpose / fft2 / transpose-back) for the synchronized variants."""

from __future__ import annotations

import jax
import numpy as np

from repro.core import plan, variants

from .common import emit, time_fn


def run(n: int = 512) -> None:
    planner = plan.Planner(mode="estimate", backends=("jnp",))
    rng = np.random.default_rng(0)
    x = jax.numpy.asarray(rng.standard_normal((n, n)), jax.numpy.float32)

    stages = variants.staged_for_loop(x, planner)
    val = x
    total = 0.0
    for name, fn in stages:
        t = time_fn(fn, val)
        val = fn(val)
        total += t
        emit(f"fig2/staged/{name}/n{n}", t)
    emit(f"fig2/staged/total/n{n}", total)

    fused = jax.jit(lambda a: variants.run_variant("for_loop", a, planner))
    t_fused = time_fn(fused, x)
    emit(f"fig2/fused_for_loop/n{n}", t_fused,
         f"stage_sum_over_fused={total / t_fused:.2f}")


if __name__ == "__main__":
    run()
