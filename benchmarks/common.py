"""Benchmark utilities: median-of-k timing (paper: median of 50; scaled to
CPU), CSV output `name,us_per_call,derived`."""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, reps: int = 7, warmup: int = 2) -> float:
    """Median wall-clock seconds per call (compiled, steady-state)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
