"""Kernel-level benchmark: matmul-FFT backends vs XLA-native FFT (per-call
time for batched 1D FFT — the paper's FFTW-backend comparison at the level
where the MXU argument lives).  Derived column reports flops and the
achieved fraction of the CPU-local roofline."""

from __future__ import annotations

import jax
import numpy as np

from repro.core import algo, plan

from .common import emit, time_fn


def run(n: int = 4096, batch: int = 64) -> None:
    rng = np.random.default_rng(0)
    x = (jax.numpy.asarray(rng.standard_normal((batch, n)), jax.numpy.float32),
         jax.numpy.asarray(rng.standard_normal((batch, n)), jax.numpy.float32))
    for backend in ("jnp", "jnp_karatsuba", "xla_native"):
        planner = plan.Planner(mode="estimate", backends=(backend,))
        pl = planner.plan(n, "c2c", batch=batch)
        fn = jax.jit(lambda a, _p=pl: plan.execute(_p, a))
        t = time_fn(fn, x)
        emit(f"kernels/fft1d/{backend}/n{n}b{batch}", t,
             f"gflops={pl.flops(batch) / 1e9:.2f};"
             f"achieved_gflops_per_s={pl.flops(batch) / t / 1e9:.1f}")


if __name__ == "__main__":
    run()
