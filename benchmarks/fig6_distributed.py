"""Paper Fig. 6: distributed strong scaling — communication backends.

Compares the monolithic all_to_all ("MPI parcelport"), the chunked pipelined
exchange ("LCI parcelport" analogue), and the AGAS gather emulation, on 8
fake devices: wall time (structural on CPU) + per-device collective bytes
parsed from the compiled HLO (the roofline-relevant number: AGAS moves ~P x
the bytes; pipelined moves the same bytes as collective but in overlap-ready
chunks).

Everything goes through the planned front-end (`repro.core.api.plan_nd` +
the `fftn` family) with forced decompositions: the 1D slab layout (8-way
mesh, 2D r2c, including the planned transposed output layout that skips
the restore exchange), the 2D pencil layout (4x2 mesh, 3D c2c with
row/column communicators, mixed per-axis backend selection), the 4D k=3
pencil chain (2x2x2 mesh), and the factor-split distributed 1D transform
(three 1/P exchanges vs one full gather).

A final section reproduces the paper's plan-mode trade-off at BOTH planning
layers: the comm layer (roofline ESTIMATE choice vs on-mesh MEASURE choice
per exchange, with proof that the second measured call is a pure wisdom
hit) and the new decomposition layer (`mode="estimate"` vs
`mode="measured"` in `plan_nd`, with the one-off finalist-timing cost).

The multi-device part runs in a subprocess (device-count override is
process-local).
"""

from __future__ import annotations

import os
import subprocess
import sys


def run() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig6_distributed", "--worker"],
        env=env, cwd=root, capture_output=True, text=True, timeout=1200)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr[-4000:])
        raise RuntimeError("fig6 worker failed")


def _worker() -> None:
    import time

    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import api
    from repro.core import comm as comm_mod
    from repro.core import plan
    from repro.launch.dryrun import parse_collectives

    from benchmarks.common import emit, time_fn

    mesh = jax.make_mesh((8,), ("fft",))
    planner = plan.Planner(mode="estimate", backends=("jnp",))
    rng = np.random.default_rng(0)
    for n in (256, 512):
        x = rng.standard_normal((n, n)).astype(np.float32)
        xs = jax.device_put(x, NamedSharding(mesh, P("fft", None)))
        base = None
        for comm in ("collective", "pipelined", "agas"):
            nd = api.plan_nd((n, n), "r2c", mesh=mesh, comm=comm,
                             planner=planner, decomp="slab", axes=("fft",))
            fn = jax.jit(lambda a, _p=nd: api.execute_nd(
                _p, a, mesh=mesh, planner=planner))
            t = time_fn(fn, xs)
            lowered = fn.lower(xs)
            _, counts, wire = parse_collectives(
                lowered.compile().as_text(), with_wire=True)
            wb = sum(wire.values())
            if comm == "collective":
                base = wb
            emit(f"fig6/{comm}/n{n}", t,
                 f"wire_bytes_per_dev={wb:.0f};rel_wire={wb / base:.2f};"
                 f"n_collectives={sum(counts.values())}")
        # beyond-paper: the PLANNED transposed output layout (skip exchange
        # #2) — the §Perf-A winning configuration, now an NdPlan field
        # instead of a 2D-only executor flag; wall-clock ground truth
        nd = api.plan_nd((n, n), "r2c", mesh=mesh, comm="collective",
                         planner=planner, decomp="slab", axes=("fft",),
                         output_layout="transposed")
        fn_kt = jax.jit(lambda a, _p=nd: api.execute_nd(
            _p, a, mesh=mesh, planner=planner))
        t_kt = time_fn(fn_kt, xs)
        _, counts, wire = parse_collectives(
            fn_kt.lower(xs).compile().as_text(), with_wire=True)
        wb = sum(wire.values())
        emit(f"fig6/transposed_layout/n{n}", t_kt,
             f"wire_bytes_per_dev={wb:.0f};rel_wire={wb / base:.2f};"
             f"n_collectives={sum(counts.values())}")

    # distributed 1D (factor split): the gather-local alternative moves the
    # whole array through one link; the factor split moves 3 x 1/p of it
    n1d = 1 << 20
    nd1 = api.plan_nd((n1d,), "c2c", mesh=mesh, comm="collective",
                      planner=planner, decomp="factor1d", axes=("fft",))
    pair1 = tuple(
        jax.device_put(rng.standard_normal((n1d,)).astype(np.float32),
                       NamedSharding(mesh, P("fft"))) for _ in range(2))
    fn1 = jax.jit(lambda a, b, _p=nd1: api.execute_nd(
        _p, (a, b), mesh=mesh, planner=planner))
    t1 = time_fn(fn1, *pair1)
    _, counts, wire = parse_collectives(
        fn1.lower(*pair1).compile().as_text(), with_wire=True)
    emit(f"fig6/factor1d/n{n1d}", t1,
         f"wire_bytes_per_dev={sum(wire.values()):.0f};"
         f"n_collectives={sum(counts.values())};"
         f"factors={nd1.factors[0]}x{nd1.factors[1]}")

    # pencil decomposition (P3DFFT-style) x comm backend on a 4x2 mesh:
    # same exchange layer, but collectives stay inside row/column
    # communicators, so per-exchange wire bytes scale with the communicator
    # size rather than the full device count.
    mesh2 = jax.make_mesh((4, 2), ("mx", "my"))
    nx, ny, nz = 32, 64, 64
    pair = tuple(
        jax.device_put(rng.standard_normal((nx, ny, nz)).astype(np.float32),
                       NamedSharding(mesh2, P("mx", "my", None)))
        for _ in range(2))
    base = None
    pencil_comms = [("collective",) * 2, ("pipelined",) * 2, ("agas",) * 2,
                    ("collective", "pipelined")]
    for comms in pencil_comms:
        tag = "+".join(sorted(set(comms))) if len(set(comms)) > 1 \
            else comms[0]
        ndp = api.plan_nd((nx, ny, nz), "c2c", mesh=mesh2, comm=comms,
                          planner=planner, decomp="pencil",
                          axes=("mx", "my"))
        fn = jax.jit(lambda a, b, _p=ndp: api.execute_nd(
            _p, (a, b), mesh=mesh2, planner=planner))
        t = time_fn(fn, *pair)
        _, counts, wire = parse_collectives(
            fn.lower(*pair).compile().as_text(), with_wire=True)
        wb = sum(wire.values())
        if base is None:
            base = wb
        emit(f"fig6/pencil_{tag}/x{nx}y{ny}z{nz}", t,
             f"wire_bytes_per_dev={wb:.0f};rel_wire={wb / base:.2f};"
             f"n_collectives={sum(counts.values())}")
    # 4D multi-axis pencil: the k=3 exchange chain on a 2x2x2 mesh (one
    # exchange per adjacent pair of sharded axes, each inside its own
    # plane communicator)
    mesh3 = jax.make_mesh((2, 2, 2), ("ma", "mb", "mc"))
    shape4 = (16, 16, 32, 32)
    pair4 = tuple(
        jax.device_put(rng.standard_normal(shape4).astype(np.float32),
                       NamedSharding(mesh3, P("ma", "mb", "mc", None)))
        for _ in range(2))
    nd4 = api.plan_nd(shape4, "c2c", mesh=mesh3, comm="collective",
                      planner=planner, decomp="pencil",
                      axes=("ma", "mb", "mc"))
    fn4 = jax.jit(lambda a, b, _p=nd4: api.execute_nd(
        _p, (a, b), mesh=mesh3, planner=planner))
    t4 = time_fn(fn4, *pair4)
    _, counts, wire = parse_collectives(
        fn4.lower(*pair4).compile().as_text(), with_wire=True)
    emit(f"fig6/pencil4d_k3/{'x'.join(str(s) for s in shape4)}", t4,
         f"wire_bytes_per_dev={sum(wire.values()):.0f};"
         f"n_collectives={sum(counts.values())}")

    # r2c pencil (padded half spectrum) with the planned backend choice
    xr = jax.device_put(
        rng.standard_normal((nx, ny, nz)).astype(np.float32),
        NamedSharding(mesh2, P("mx", "my", None)))
    ndr = api.plan_nd((nx, ny, nz), "r2c", mesh=mesh2, comm="auto",
                      planner=planner, decomp="pencil", axes=("mx", "my"))
    fn = jax.jit(lambda a, _p=ndr: api.execute_nd(
        _p, a, mesh=mesh2, planner=planner))
    t = time_fn(fn, xr)
    _, counts, wire = parse_collectives(
        fn.lower(xr).compile().as_text(), with_wire=True)
    wb = sum(wire.values())
    emit(f"fig6/pencil_r2c_auto/x{nx}y{ny}z{nz}", t,
         f"wire_bytes_per_dev={wb:.0f};rel_wire={wb / base:.2f};"
         f"n_collectives={sum(counts.values())}")

    # ------------------------------------------------------------------
    # estimate vs measure: the paper's plan-mode trade-off applied to the
    # parcelport choice, side by side (Figs. 3-5 logic at the comm layer)
    # ------------------------------------------------------------------
    for n in (256, 512):
        x = rng.standard_normal((n, n)).astype(np.float32)
        xs = jax.device_put(x, NamedSharding(mesh, P("fft", None)))
        est_choice = comm_mod.plan_comm(n, n, 8, hw=planner.hw)
        t0 = time.perf_counter()
        meas_choice = comm_mod.measure_comm_slab(n, n, mesh, "fft",
                                                 wisdom=planner.wisdom)
        plan_cost = time.perf_counter() - t0

        def timed_slab(choice):
            nd = api.plan_nd((n, n), "r2c", mesh=mesh, comm=choice,
                             planner=planner, decomp="slab", axes=("fft",))
            return time_fn(jax.jit(lambda a, _p=nd: api.execute_nd(
                _p, a, mesh=mesh, planner=planner)), xs)

        t_meas = timed_slab(meas_choice)
        t_est = timed_slab(est_choice)
        # second measured call: pure wisdom hit, zero timing probes
        probes = comm_mod.MEASURE_STATS["timed"]
        comm_mod.measure_comm_slab(n, n, mesh, "fft", wisdom=planner.wisdom)
        assert comm_mod.MEASURE_STATS["timed"] == probes
        emit(f"fig6/choice_slab/n{n}", t_meas,
             f"estimate={est_choice};measured={meas_choice};"
             f"t_estimate_choice={t_est * 1e3:.2f}ms;"
             f"measure_cost_s={plan_cost:.2f};rehit_probes=0")
    est0, est1 = comm_mod.plan_comm_pencil((nx, ny, nz), (4, 2),
                                           hw=planner.hw)
    t0 = time.perf_counter()
    m0, m1 = comm_mod.measure_comm_pencil((nx, ny, nz), mesh2, ("mx", "my"),
                                          wisdom=planner.wisdom)
    plan_cost = time.perf_counter() - t0
    ndm = api.plan_nd((nx, ny, nz), "c2c", mesh=mesh2, comm=(m0, m1),
                      planner=planner, decomp="pencil", axes=("mx", "my"))
    t_meas = time_fn(jax.jit(lambda a, b, _p=ndm: api.execute_nd(
        _p, (a, b), mesh=mesh2, planner=planner)), *pair)
    emit(f"fig6/choice_pencil/x{nx}y{ny}z{nz}", t_meas,
         f"estimate={est0}+{est1};measured={m0}+{m1};"
         f"measure_cost_s={plan_cost:.2f}")

    # ------------------------------------------------------------------
    # the same trade-off one layer up: decomposition choice by roofline
    # ESTIMATE vs on-mesh MEASURED finalist timing (plan_nd's two modes)
    # ------------------------------------------------------------------
    for shape, kind, m, axes in (((64, 512), "r2c", mesh, ("fft",)),
                                 ((nx, ny, nz), "c2c", mesh2, ("mx", "my"))):
        est_nd = api.plan_nd(shape, kind, mesh=m, axes=axes, planner=planner)
        t0 = time.perf_counter()
        meas_nd = api.plan_nd(shape, kind, mesh=m, axes=axes,
                              planner=planner, mode="measured")
        plan_cost = time.perf_counter() - t0
        tag = "x".join(str(s) for s in shape)
        emit(f"fig6/choice_decomp/{tag}", meas_nd.measured_cost,
             f"estimate={est_nd.decomp};measured={meas_nd.decomp};"
             f"est_cost={est_nd.est_cost:.2e};"
             f"measure_cost_s={plan_cost:.2f}")


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker()
    else:
        run()
