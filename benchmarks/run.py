"""Benchmark entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""

from __future__ import annotations

import sys


def main() -> None:
    print("name,us_per_call,derived")
    from . import (bench_kernels, fig1_variants, fig2_decomposition,
                   fig3_planning, fig5_plan_time, fig6_distributed)
    fig1_variants.run()
    fig2_decomposition.run()
    fig3_planning.run()
    fig5_plan_time.run()
    fig6_distributed.run()
    bench_kernels.run()


if __name__ == "__main__":
    main()
