"""CI-sized benchmark subset + regression gate (the perf trajectory).

Runs a small, fixed set of distributed-FFT cases on the 8-way fake-device
CPU mesh (the fig2 decomposition verdicts + fig6-style timed executions,
including the PR-4 additions: multi-axis 4D pencil, the factor-split
distributed 1D, and the planned transposed slab layout) and emits
``BENCH_ci.json``:

* per-case **best-of-12 ms** (the min is the regression-gate statistic:
  robust to scheduler noise spikes) and the executed plan's verdict
  (decomp / mesh axes / comm / output layout / factors);
* a **calibration** time (one planned local 2D FFT) and each case's
  ``rel = ms / calib_ms`` — informational context for the artifact.

Gate semantics (``--baseline benchmarks/baseline_ci.json``): each case's
ms ratio vs baseline is compared against the MEDIAN ratio across cases
(the machine-speed factor), so a uniformly slower CI runner trips
nothing — only a case that regressed by more than ``--tolerance``
(default 25%) *relative to its peers* fails, and a missing case always
fails.  ``BENCH_SKIP_GATE=1`` reports without
failing (the CI override label sets it); ``--write-baseline`` refreshes
the committed baseline; ``--inject-slowdown CASE:FACTOR`` multiplies one
case's measurement after the fact — the knob used to demonstrate the gate
trips (see benchmarks/README.md).

The measurement runs in a subprocess (the fake-device-count override is
process-local), exactly like fig6.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

SCHEMA = "repro-bench-ci"
VERSION = 1
DEFAULT_TOLERANCE = 0.25


def _worker(out_path: str) -> None:
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    import time as _time

    from repro.core import api, plan

    def best_of(fn, *args, reps: int = 12, warmup: int = 3) -> float:
        """Best-of-k wall seconds per call: the min is the right statistic
        for a regression gate (robust to scheduler noise spikes, which on
        shared CI runners dwarf the median's jitter at ms scale)."""
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
        best = float("inf")
        for _ in range(reps):
            t0 = _time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, _time.perf_counter() - t0)
        return best

    planner = plan.Planner(mode="estimate", backends=("jnp",))
    rng = np.random.default_rng(0)
    mesh8 = jax.make_mesh((8,), ("fft",))
    mesh42 = jax.make_mesh((4, 2), ("mx", "my"))
    mesh222 = jax.make_mesh((2, 2, 2), ("ma", "mb", "mc"))

    def timed(nd, mesh, x):
        if nd.kind == "c2c" and not isinstance(x, tuple):
            x = (x, np.zeros_like(x))
        if isinstance(x, tuple):
            arrs = tuple(jax.numpy.asarray(a) for a in x)
            fn = jax.jit(lambda a, b, _p=nd: api.execute_nd(
                _p, (a, b), mesh=mesh, planner=planner))
        else:
            arrs = (jax.numpy.asarray(x),)
            fn = jax.jit(lambda a, _p=nd: api.execute_nd(
                _p, a, mesh=mesh, planner=planner))
        return best_of(fn, *arrs) * 1e3          # best-of ms

    def plan_record(nd):
        return {"decomp": nd.decomp, "mesh_axes": list(nd.mesh_axes),
                "comm": list(nd.comm), "output_layout": nd.output_layout,
                "factors": list(nd.factors)}

    # calibration: one planned local 2D r2c FFT on a single device —
    # everything else is reported relative to this machine-speed probe
    x256 = rng.standard_normal((256, 256)).astype(np.float32)
    nd_cal = api.plan_nd((256, 256), "r2c", planner=planner)
    calib_ms = timed(nd_cal, None, x256)

    cases = {}

    def case(name, nd, mesh, x):
        ms = timed(nd, mesh, x)
        cases[name] = {"ms": ms, "rel": ms / calib_ms,
                       "plan": plan_record(nd)}

    xs = jax.device_put(x256, NamedSharding(mesh8, P("fft", None)))
    case("slab_r2c_256",
         api.plan_nd((256, 256), "r2c", mesh=mesh8, planner=planner,
                     decomp="slab", axes=("fft",), comm="collective"),
         mesh8, xs)
    case("slab_r2c_256_transposed",
         api.plan_nd((256, 256), "r2c", mesh=mesh8, planner=planner,
                     decomp="slab", axes=("fft",), comm="collective",
                     output_layout="transposed"),
         mesh8, xs)

    x3 = rng.standard_normal((32, 64, 64)).astype(np.float32)
    pair3 = tuple(jax.device_put(a, NamedSharding(mesh42,
                                                  P("mx", "my", None)))
                  for a in (x3, np.zeros_like(x3)))
    case("pencil_c2c_32x64x64",
         api.plan_nd((32, 64, 64), "c2c", mesh=mesh42, planner=planner,
                     decomp="pencil", axes=("mx", "my"), comm="auto"),
         mesh42, pair3)

    x4 = rng.standard_normal((16, 16, 32, 32)).astype(np.float32)
    pair4 = tuple(jax.device_put(a, NamedSharding(
        mesh222, P("ma", "mb", "mc", None)))
        for a in (x4, np.zeros_like(x4)))
    case("pencil4d_c2c_16x16x32x32_k3",
         api.plan_nd((16, 16, 32, 32), "c2c", mesh=mesh222, planner=planner,
                     decomp="pencil", axes=("ma", "mb", "mc"),
                     comm="collective"),
         mesh222, pair4)

    n1d = 1 << 16
    x1 = rng.standard_normal((n1d,)).astype(np.float32)
    pair1 = tuple(jax.device_put(a, NamedSharding(mesh8, P("fft")))
                  for a in (x1, np.zeros_like(x1)))
    case("factor1d_c2c_65536",
         api.plan_nd((n1d,), "c2c", mesh=mesh8, planner=planner,
                     decomp="factor1d", axes=("fft",), comm="collective"),
         mesh8, pair1)

    # free-choice planner verdicts (no timing): the fig2 decomposition
    # column at CI scale — a planner change that flips one of these shows
    # up in the artifact diff even when the timings sit inside tolerance
    verdicts = {}
    for tag, shape, kind, mesh in (
            ("slab_1024sq", (1024, 1024), "r2c", {"fft": 8}),
            ("pencil_128cube", (128, 128, 128), "c2c", {"mx": 4, "my": 2}),
            ("factor1d_1M", (1 << 20,), "c2c", {"fft": 8}),
            ("local_64sq", (64, 64), "r2c", {"fft": 8})):
        nd = api.plan_nd(shape, kind, mesh=mesh, planner=planner)
        verdicts[tag] = nd.decomp

    out = {"schema": SCHEMA, "version": VERSION, "calib_ms": calib_ms,
           "cases": cases, "verdicts": verdicts}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")


def _gate(results: dict, baseline: dict, tolerance: float) -> int:
    """Compare per-case ms ratios vs baseline against the MEDIAN ratio (the
    machine-speed factor): a uniformly slower runner shifts every ratio and
    trips nothing; one case that regressed relative to its peers exceeds
    ``median * (1 + tolerance)``.  A BACKSTOP guards the median itself:
    if it drifted more than ``2 * tolerance`` beyond the single-device
    calibration ratio, the mesh cases slowed down *as a group* relative to
    local compute (e.g. a shared exchange-layer regression) and the run
    fails even though no case stands out from its peers.  Returns the
    violation count (missing cases count)."""
    bad = 0
    ratios = {}
    for name, base in baseline.get("cases", {}).items():
        got = results["cases"].get(name)
        if got is not None:
            ratios[name] = got["ms"] / base["ms"]
    speed = sorted(ratios.values())[len(ratios) // 2] if ratios else 1.0
    calib_ratio = results["calib_ms"] / baseline["calib_ms"] \
        if baseline.get("calib_ms") else 1.0
    print(f"bench_ci gate: machine-speed factor {speed:.2f} "
          f"(median of {len(ratios)} case ratios; "
          f"calib ratio {calib_ratio:.2f})")
    backstop = calib_ratio * (1.0 + 2.0 * tolerance)
    if speed > backstop:
        print(f"BENCH GATE: the mesh cases slowed down as a group — median "
              f"ratio {speed:.2f} exceeds calibration-drift backstop "
              f"{backstop:.2f} (uniform regressions cannot hide behind "
              "the median normalization)")
        bad += 1
    for name, base in sorted(baseline.get("cases", {}).items()):
        got = results["cases"].get(name)
        if got is None:
            print(f"BENCH GATE: case {name!r} missing from results")
            bad += 1
            continue
        limit = speed * (1.0 + tolerance)
        verdict = "FAIL" if ratios[name] > limit else "ok"
        print(f"bench_ci {name}: {got['ms']:.2f} ms vs baseline "
              f"{base['ms']:.2f} ms -> ratio {ratios[name]:.2f} "
              f"(limit {limit:.2f}) [{verdict}]")
        if ratios[name] > limit:
            bad += 1
    for name in sorted(set(results["cases"]) - set(baseline.get("cases", {}))):
        print(f"bench_ci {name}: new case (no baseline) "
              f"{results['cases'][name]['ms']:.2f} ms")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_ci.json")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON to gate against")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed relative regression (0.25 = 25%%)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh the baseline file instead of gating")
    ap.add_argument("--inject-slowdown", default=None, metavar="CASE:FACTOR",
                    help="multiply case measurements (gate-trip demo); "
                         "comma-separate entries, or use CASE '*' to slow "
                         "every case (backstop demo)")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.worker:
        _worker(args.out)
        return 0

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out_path = os.path.abspath(args.out)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_ci", "--worker",
         "--out", out_path],
        env=env, cwd=root, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError("bench_ci worker failed")
    with open(out_path) as f:
        results = json.load(f)

    if args.inject_slowdown:
        for entry in args.inject_slowdown.split(","):
            name, _, factor = entry.partition(":")
            names = list(results["cases"]) if name == "*" else [name]
            for n in names:
                results["cases"][n]["ms"] *= float(factor)
                results["cases"][n]["rel"] *= float(factor)
            print(f"bench_ci: injected x{factor} slowdown into {names}")
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
            f.write("\n")

    print(f"bench_ci: calib {results['calib_ms']:.2f} ms; "
          f"verdicts {results['verdicts']}")
    if args.write_baseline:
        base_path = args.baseline or os.path.join(root, "benchmarks",
                                                  "baseline_ci.json")
        with open(base_path, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"bench_ci: baseline written to {base_path}")
        return 0
    if args.baseline:
        if not os.path.exists(args.baseline):
            # fail closed: a forgotten/renamed baseline must not silently
            # disable the gate
            print(f"bench_ci: baseline {args.baseline!r} not found — "
                  "regenerate with scripts/bench_ci.sh --write-baseline")
            return 1
        with open(args.baseline) as f:
            baseline = json.load(f)
        bad = _gate(results, baseline, args.tolerance)
        if bad and os.environ.get("BENCH_SKIP_GATE"):
            print(f"bench_ci: {bad} regression(s) IGNORED "
                  "(BENCH_SKIP_GATE set)")
        elif bad:
            print(f"bench_ci: {bad} regression(s) beyond "
                  f"{args.tolerance:.0%} — failing (set BENCH_SKIP_GATE=1 "
                  "or apply the 'bench-regression-ok' label to override; "
                  "refresh with scripts/bench_ci.sh --write-baseline)")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
