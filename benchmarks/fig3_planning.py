"""Paper Figs. 3/4: execution time per backend under estimated vs measured
planning.  Backends: our matmul-FFT ('jnp'), its Karatsuba variant, and the
XLA-native FFT ('xla_native' — the FFTW-class library baseline)."""

from __future__ import annotations

import jax
import numpy as np

from repro.core import plan, variants

from .common import emit, time_fn

BACKENDS = ("jnp", "jnp_karatsuba", "xla_native")


def run(n: int = 512) -> None:
    rng = np.random.default_rng(0)
    x = jax.numpy.asarray(rng.standard_normal((n, n)), jax.numpy.float32)
    for mode in ("estimate", "measured"):
        for backend in BACKENDS:
            planner = plan.Planner(mode=mode, backends=(backend,),
                                   hardware=plan.CPU_LOCAL)
            fn = jax.jit(lambda a: variants.run_variant("for_loop", a, planner))
            t = time_fn(fn, x)
            row = planner.plan(n, "c2c")
            emit(f"fig3/{mode}/{backend}/n{n}", t,
                 f"factors={'x'.join(map(str, row.factors)) or 'native'}")


if __name__ == "__main__":
    run()
